"""Routing as a first-class layer: the ISSUE 5 contract.

Four layers of evidence:

1. **Golden regression** — ``static_ecmp`` (the default) reproduces the
   pre-routing-layer scalar driver bit-exactly on the existing golden
   scenarios (literals captured before the per-TC refactor, imported
   from test_pfc_priority), and the vector engines stay inside their
   established bounds (numpy ~1e-13, jax <= 5e-4).

2. **Cross-engine equivalence** — every dynamic mode (weighted_ecmp /
   adaptive / spray), link failures, WRR scheduling and per-TC host PFC
   agree between the scalar driver and the float64 numpy backend to
   ~1e-9, including identical reroute counts and drop accounting.

3. **Hypothesis property** — under a single mid-burst uplink failure,
   adaptive routing never delivers fewer total bytes than static ECMP
   (static keeps hashing onto the dead spine; adaptive reroutes).

4. **Acceptance** — ``scenarios.routing_grid`` (routing mode x failure
   schedule, per-point parameters) runs as ONE vector program in which
   adaptive and spray complete the post-failure incast while static
   ECMP stalls, with reroutes and per-uplink utilization surfaced.
"""
import dataclasses
import math
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import simulator as S
from repro.core.datapath import QoS
from repro.fabric import scenarios as SC
from repro.fabric import topology
from repro.fabric.fabric import FabricConfig, Flow, run_fabric
from repro.fabric.routing import (ROUTING_MODES, RoutingConfig,
                                  adaptive_pick, flowlet_hash,
                                  spray_weights, weighted_pick)
from repro.fabric.switch import OutputPort, SwitchConfig
from repro.fabric.vector import FabricSweepParams, run_fabric_sweep
from test_pfc_priority import GOLDEN, _check_scalar_golden, \
    _golden_scenario, _maxrel

EXAMPLES = int(os.environ.get("FABRIC_TEST_EXAMPLES", "2"))
DEEP_EXAMPLES = max(20, EXAMPLES)


# --------------------------------------------------------------------------- #
# routing-policy units (pure helpers shared with the vector engines)
# --------------------------------------------------------------------------- #
def test_routing_config_validates():
    assert RoutingConfig().mode == "static_ecmp"
    assert not RoutingConfig().is_dynamic
    assert RoutingConfig(mode="spray").is_dynamic
    assert [RoutingConfig(mode=m).mode_code()
            for m in ROUTING_MODES] == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        RoutingConfig(mode="ecmp5")
    with pytest.raises(ValueError):
        RoutingConfig(flowlet_gap_us=0.0)
    with pytest.raises(ValueError):
        RoutingConfig(hysteresis_frac=-0.1)


def test_flowlet_hash_deterministic_and_spread():
    vals = [flowlet_hash(fid, k) for fid in range(16) for k in range(16)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert flowlet_hash(3, 7) == flowlet_hash(3, 7)
    assert len(set(vals)) > 200                   # no degenerate clumping


def test_weighted_pick_follows_weights():
    # h below the first weight's share picks 0, above picks 1
    assert weighted_pick([3.0, 1.0], 0.5) == 0
    assert weighted_pick([3.0, 1.0], 0.8) == 1
    assert weighted_pick([0.0, 1.0], 0.0) == 1    # zero-weight skipped
    assert weighted_pick([1.0, 1.0], 0.999) == 1


def test_adaptive_pick_hysteresis_and_failure():
    occ = [100.0, 90.0, 500.0]
    up = [True, True, True]
    # inside the hysteresis band: stay
    assert adaptive_pick(occ, up, cur=0, hyst_bytes=50.0) == 0
    # beyond the band: move to the least congested
    assert adaptive_pick(occ, up, cur=2, hyst_bytes=50.0) == 1
    # dead current path: move even inside the band
    assert adaptive_pick(occ, [False, True, True], 0, 1e9) == 1
    # everything dead: stuck on cur
    assert adaptive_pick(occ, [False] * 3, 0, 0.0) == 0
    # first-minimum tie-break (matches argmin)
    assert adaptive_pick([5.0, 5.0], [True, True], 1, 0.0) == 1
    assert adaptive_pick([5.0, 5.0, 0.0], [True] * 3, 0, 1.0) == 2


def test_spray_weights_proportional_and_fallback():
    w = spray_weights([0.0, 500.0], [True, True], 1000.0, cur=0)
    assert w[0] == pytest.approx(2.0 / 3.0) and sum(w) == pytest.approx(1)
    # down candidates get nothing
    w = spray_weights([0.0, 0.0], [True, False], 1000.0, cur=1)
    assert w == [1.0, 0.0]
    # nothing up: stay on cur
    assert spray_weights([0.0, 0.0], [False, False], 1000.0, 1) == [0, 1]


# --------------------------------------------------------------------------- #
# topology link-failure schedule
# --------------------------------------------------------------------------- #
def test_fail_link_schedule_and_validation():
    topo = topology.incast_fabric(2)
    topo.fail_link("leaf0", "spine0", at_us=100.0, restore_us=200.0)
    # bidi by default: both directions share the window
    assert topo.link_down[("leaf0", "spine0")] == (100.0, 200.0)
    assert topo.link_down[("spine0", "leaf0")] == (100.0, 200.0)
    assert topo.link_up_at(("leaf0", "spine0"), 99.0)
    assert not topo.link_up_at(("leaf0", "spine0"), 100.0)
    assert topo.link_up_at(("leaf0", "spine0"), 200.0)
    ft = topo.failure_ticks(1.0)
    assert ft[("leaf0", "spine0")] == (100, 200)
    # permanent failures use the int32-safe sentinel
    topo.fail_link("leaf0", "spine1", at_us=50.0)
    assert topo.failure_ticks(1.0)[("leaf0", "spine1")] == \
        (50, topology.NEVER_TICK)
    topo.validate()
    with pytest.raises(ValueError):
        topo.fail_link("leaf0", "nope", at_us=1.0)
    with pytest.raises(ValueError):
        topo.fail_link("leaf0", "spine0", at_us=5.0, restore_us=5.0)
    assert topo.candidate_spines("h0_0", "h1_0") == ["spine0", "spine1"]
    assert topo.candidate_spines("h0_0", "h0_1") == []


# --------------------------------------------------------------------------- #
# golden regression: static_ecmp == pre-refactor driver
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_static_ecmp_scalar_bit_equal(key):
    """The routing layer's static mode (now an explicit RoutingConfig)
    reproduces the pre-routing-layer scalar numerics bit-for-bit, with
    zero reroutes and populated uplink utilization."""
    sc = _golden_scenario(key)
    sc.fabric.routing = RoutingConfig(mode="static_ecmp")
    r = sc.run()
    _check_scalar_golden(r, GOLDEN[key])
    assert r.reroute_count == 0
    assert all(v == 0 for v in r.flow_reroutes.values())
    assert r.uplink_util and all(0.0 <= u <= 1.0
                                 for u in r.uplink_util.values())
    assert r.uplink_imbalance() > 0.0


@pytest.mark.slow
def test_static_ecmp_vector_within_established_bounds():
    """Vector engines under an explicit static RoutingConfig: numpy
    ~1e-13, jax <= 5e-4 against the golden literals."""
    sc = _golden_scenario("incast8_jet_pfc")
    sc.fabric.routing = RoutingConfig(mode="static_ecmp")
    g = GOLDEN["incast8_jet_pfc"]
    for backend, tol in (("numpy", 1e-13), ("jax", 5e-4)):
        out = run_fabric_sweep([sc], backend=backend)
        assert _maxrel(out["flow_goodput_gbps"][0], g["goodput"]) <= tol
        assert _maxrel(out["flow_completion_us"][0],
                       g["completion"]) <= tol
        assert out["pause_fanout"][0] == g["pause_fanout"]
        assert out["reroute_count"][0] == 0


# --------------------------------------------------------------------------- #
# cross-engine equivalence in dynamic-routing land
# --------------------------------------------------------------------------- #
def _scalar_ref(sc):
    r = sc.run()
    F = len(sc.flows)
    return r, np.array([r.flow_goodput_gbps[f] for f in range(F)]), \
        np.array([r.flow_completion_us[f] for f in range(F)])


# static stays in the fast tier as the smoke case; the dynamic modes
# re-run the same scalar reference and ride the slow job
@pytest.mark.parametrize("mode", [
    "static_ecmp",
    pytest.param("weighted_ecmp", marks=pytest.mark.slow),
    pytest.param("adaptive", marks=pytest.mark.slow),
    pytest.param("spray", marks=pytest.mark.slow)])
def test_dynamic_modes_numpy_matches_scalar(mode):
    """Every routing mode under a mid-burst link failure: the float64
    numpy backend reproduces the scalar driver (goodput, completion,
    drops, reroute counts)."""
    sc = SC.link_failure_incast(routing=mode, sim_time_s=0.005,
                                burst_mb=1.0)
    r, gp, cp = _scalar_ref(sc)
    out = run_fabric_sweep([sc], backend="numpy")
    assert _maxrel(out["flow_goodput_gbps"][0], gp) <= 1e-9
    assert _maxrel(out["flow_completion_us"][0], cp) <= 1e-9
    assert out["switch_dropped_bytes"][0] == pytest.approx(
        r.switch_dropped_bytes, rel=1e-9)
    assert out["reroute_count"][0] == r.reroute_count
    np.testing.assert_array_equal(
        out["flow_reroutes"][0],
        [r.flow_reroutes[f] for f in range(len(sc.flows))])


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["adaptive", "spray"])
def test_dynamic_modes_with_pfc_numpy_matches_scalar(mode):
    """Candidate-ingress pause targeting agrees across engines when a
    dynamic mode runs with PFC enabled."""
    sc = SC.link_failure_incast(routing=mode, pfc=True, sim_time_s=0.004,
                                burst_mb=1.0)
    r, gp, _ = _scalar_ref(sc)
    out = run_fabric_sweep([sc], backend="numpy")
    assert _maxrel(out["flow_goodput_gbps"][0], gp) <= 1e-9
    assert out["pause_fanout"][0] == r.pause_fanout
    assert out["ecn_marked_bytes"][0] == pytest.approx(
        r.ecn_marked_bytes, rel=1e-9, abs=1e-6)


@pytest.mark.slow
def test_uplink_util_matches_scalar():
    sc = SC.link_failure_incast(routing="adaptive", sim_time_s=0.004,
                                burst_mb=1.0)
    r = sc.run()
    out = run_fabric_sweep([sc], backend="numpy")
    fsp = FabricSweepParams.from_scenarios([sc])
    up = fsp.stage_mask[1]
    for pid, key in enumerate(fsp.port_keys):
        if up[pid]:
            assert out["uplink_util"][0, pid] == pytest.approx(
                r.uplink_util[key], rel=1e-9, abs=1e-12)
    assert out["uplink_util_max"][0] >= out["uplink_util_mean"][0] > 0.0


@pytest.mark.slow
def test_spray_settle_delays_delivery():
    """The reorder-settling penalty pushes completion later (never
    earlier), and settle=0 is pass-through."""
    fcts = []
    for settle in (0.0, 40.0):
        sc = SC.link_failure_incast(routing="spray", sim_time_s=0.006,
                                    burst_mb=0.5, fail_at_us=math.inf)
        sc.fabric.routing = RoutingConfig(mode="spray",
                                          spray_settle_us=settle)
        r, gp, cp = _scalar_ref(sc)
        out = run_fabric_sweep([sc], backend="numpy")
        assert _maxrel(out["flow_completion_us"][0], cp) <= 1e-9
        fcts.append(r.incast_completion_us)
    assert math.isfinite(fcts[0]) and math.isfinite(fcts[1])
    assert fcts[1] >= fcts[0] + 30.0              # ~the added settle


# --------------------------------------------------------------------------- #
# acceptance: one vector program, mode x failure grid
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def routing_grid_out():
    scens, pts = SC.routing_grid(sim_time_s=0.01, burst_mb=1.0,
                                 fail_at_us=(math.inf, 150.0))
    out = run_fabric_sweep(scens, backend="jax")
    return pts, out


def test_routing_grid_one_program_acceptance(routing_grid_out):
    """ISSUE 5 acceptance: routing mode AND failure schedule vary across
    the points of ONE vector program; post-failure, adaptive and spray
    complete the incast that static ECMP cannot."""
    pts, out = routing_grid_out
    fct = {(p["routing"], math.isfinite(p["fail_at_us"])):
           out["incast_completion_us"][i] for i, p in enumerate(pts)}
    # no failure: everything completes
    for mode in ("static_ecmp", "adaptive", "spray"):
        assert math.isfinite(fct[(mode, False)])
    # mid-burst uplink failure: static stalls on the dead spine...
    assert not math.isfinite(fct[("static_ecmp", True)])
    # ...while the dynamic modes reroute and finish
    assert math.isfinite(fct[("adaptive", True)])
    assert math.isfinite(fct[("spray", True)])
    assert fct[("adaptive", True)] < 0.8 * out["incast_completion_us"] \
        .max(where=np.isfinite(out["incast_completion_us"]),
             initial=1e18)


def test_routing_grid_reroutes_and_util(routing_grid_out):
    pts, out = routing_grid_out
    for i, p in enumerate(pts):
        if p["routing"] == "adaptive":
            assert out["reroute_count"][i] > 0
        if p["routing"] == "static_ecmp":
            assert out["reroute_count"][i] == 0
        assert out["uplink_util_max"][i] > 0.0


@pytest.mark.slow
def test_restore_gives_dynamic_fct_advantage():
    """With the link restored before sim end, every mode completes but
    adaptive/spray beat static's post-failure FCT outright."""
    mk = lambda m: SC.link_failure_incast(       # noqa: E731
        routing=m, sim_time_s=0.02, burst_mb=1.0, fail_at_us=150.0,
        restore_us=6000.0)
    out = run_fabric_sweep([mk("static_ecmp"), mk("adaptive"),
                            mk("spray")], backend="numpy")
    st_fct, ad_fct, sp_fct = out["incast_completion_us"]
    assert math.isfinite(st_fct)
    assert ad_fct < st_fct and sp_fct < st_fct
    assert st_fct > 6000.0                       # stalled until restore


# --------------------------------------------------------------------------- #
# property: adaptive never delivers less than static under one failure
# --------------------------------------------------------------------------- #
def _adaptive_vs_static_case(n_senders, burst_kb, fail_spine, fail_at_us):
    mk = lambda mode: SC.link_failure_incast(    # noqa: E731
        n_senders=n_senders, routing=mode, burst_mb=burst_kb / 1e3,
        fail_at_us=float(fail_at_us), fail_spine=fail_spine,
        with_victim=False, sim_time_s=0.004)
    out = run_fabric_sweep([mk("static_ecmp"), mk("adaptive")],
                           backend="numpy")
    static, adaptive = out["flow_delivered_bytes"].sum(-1)
    # 1% tolerance for inter-class scheduling noise on the shared
    # surviving uplinks; the interesting failures give adaptive a
    # decisive margin, ties happen when the failure lands post-burst
    assert adaptive >= static * 0.99 - 1e-6


@pytest.mark.slow
@settings(max_examples=EXAMPLES, deadline=None)
@given(st.integers(3, 6), st.integers(200, 1500), st.integers(0, 1),
       st.integers(20, 3000))
def test_adaptive_never_trails_static_under_failure(
        n_senders, burst_kb, fail_spine, fail_at_us):
    _adaptive_vs_static_case(n_senders, burst_kb, fail_spine, fail_at_us)


@pytest.mark.slow
@settings(max_examples=DEEP_EXAMPLES, deadline=None)
@given(st.integers(3, 6), st.integers(200, 1500), st.integers(0, 1),
       st.integers(20, 3000))
def test_adaptive_never_trails_static_under_failure_deep(
        n_senders, burst_kb, fail_spine, fail_at_us):
    _adaptive_vs_static_case(n_senders, burst_kb, fail_spine, fail_at_us)


# --------------------------------------------------------------------------- #
# satellite: WRR inter-class drain (starvation regression)
# --------------------------------------------------------------------------- #
def test_wrr_port_grants_weighted_shares():
    p = OutputPort(topology.Link("a", "b", 80.0),
                   SwitchConfig(port_buffer_bytes=1 << 20,
                                scheduler="wrr",
                                wrr_quanta=(4.0, 2.0, 1.0)))
    p.enqueue(0, 500 << 10, 0.0, None, tc=0)
    p.enqueue(1, 500 << 10, 0.0, None, tc=2)
    out = dict((fid, b) for fid, b, _ in p.drain(10.0))
    # 100 KB budget split 4:1 over the two backlogged classes
    assert out[0] == pytest.approx(80e3)
    assert out[1] == pytest.approx(20e3)


def test_wrr_releases_unused_share():
    p = OutputPort(topology.Link("a", "b", 80.0),
                   SwitchConfig(port_buffer_bytes=1 << 20,
                                scheduler="wrr"))
    p.enqueue(0, 10 << 10, 0.0, None, tc=0)       # HIGH nearly empty
    p.enqueue(1, 500 << 10, 0.0, None, tc=2)
    out = dict((fid, b) for fid, b, _ in p.drain(10.0))
    assert out[0] == pytest.approx(10 << 10)      # drains fully
    assert out[1] == pytest.approx(1e5 - (10 << 10))   # LOW takes the rest


def test_wrr_prevents_low_starvation_on_saturated_port():
    """Starvation regression: a saturated port under strict priority
    starves LOW outright; WRR keeps it at its quanta share."""
    topo = topology.incast_fabric(4, host_gbps=100.0, uplink_gbps=800.0)
    flows = [Flow(src=f"h0_{i}", dst="h1_0", offered_gbps=60.0,
                  qos=QoS.HIGH, tag="hi") for i in range(3)]
    flows.append(Flow(src="h0_3", dst="h1_0", offered_gbps=40.0,
                      qos=QoS.LOW, tag="low"))
    res = {}
    for sched in ("strict", "wrr"):
        sw = SwitchConfig(pfc_enabled=False, ecn_enabled=False,
                          scheduler=sched, port_buffer_bytes=1 << 20)
        fc = FabricConfig(sim_time_s=0.004, switch=sw,
                          receiver_cfg=lambda h: S.testbed_100g("ddio"))
        res[sched] = SC.Scenario(name=sched, topology=topo, flows=flows,
                                 fabric=fc).run()
    assert res["strict"].tagged_goodput("low") < 1.0       # starved
    # quanta (4,2,1): LOW owns 1/5 of the saturated 100G downlink
    assert res["wrr"].tagged_goodput("low") > 15.0
    # work conservation: the port still runs at line rate
    for sched in res:
        tot = res[sched].tagged_goodput("hi") * 3 \
            + res[sched].tagged_goodput("low")
        assert tot == pytest.approx(100.0, rel=0.05)


def test_wrr_vector_matches_scalar_mixed_grid():
    """strict and wrr points share one sweep grid (sched is per-point)
    and reproduce the scalar driver."""
    topo = topology.incast_fabric(4, host_gbps=100.0, uplink_gbps=800.0)
    flows = [Flow(src=f"h0_{i}", dst="h1_0", offered_gbps=60.0,
                  qos=QoS(i % 3), tag="t") for i in range(4)]
    scens = []
    for sched in ("strict", "wrr"):
        sw = SwitchConfig(pfc_enabled=True, scheduler=sched,
                          port_buffer_bytes=1 << 19)
        scens.append(SC.Scenario(
            name=sched, topology=topo, flows=flows,
            fabric=FabricConfig(sim_time_s=0.003, switch=sw,
                                receiver_cfg=lambda h:
                                S.testbed_100g("ddio"))))
    out = run_fabric_sweep(scens, backend="numpy")
    for i, sc in enumerate(scens):
        r, gp, cp = _scalar_ref(sc)
        assert _maxrel(out["flow_goodput_gbps"][i], gp) <= 1e-9, sc.name
        assert out["pause_fanout"][i] == r.pause_fanout


def test_switch_config_rejects_bad_scheduler():
    with pytest.raises(ValueError):
        SwitchConfig(scheduler="drr")
    with pytest.raises(ValueError):
        SwitchConfig(scheduler="wrr", wrr_quanta=(1.0, 2.0))
    with pytest.raises(ValueError):
        SwitchConfig(scheduler="wrr", wrr_quanta=(1.0, 0.0, 2.0))


# --------------------------------------------------------------------------- #
# satellite: per-TC host PFC (receiver RNIC gate)
# --------------------------------------------------------------------------- #
def test_receiver_host_per_class_pause_unit():
    """Driving ReceiverHost directly: a LOW flood pauses only LOW; the
    legacy gate pauses everything."""
    def run_one(per_tc):
        cfg = S.testbed_100g("ddio", pfc_enabled=True,
                             host_pfc_per_tc=per_tc,
                             cpu_membw_gbps=1995.0)   # throttle the drain
        host = S.ReceiverHost(cfg, sim_ticks=400)
        per_tick = cfg.line_rate_gbps * 1e9 / 8.0 * 1e-6
        for _ in range(400):
            host.step([0.0, 0.0, per_tick])           # all LOW
        return host
    h = run_one(True)
    assert h.paused_classes == frozenset({int(QoS.LOW)})
    assert h.pfc_paused                               # legacy view agrees
    legacy = run_one(False)
    assert legacy.paused_classes == frozenset(range(3))
    assert legacy.pfc_pause_us > 0


@pytest.mark.slow
def test_host_per_tc_pfc_isolates_classes_on_access_link():
    """Fabric-level: a LOW bulk incast fills the receiver RNIC buffer;
    with the classed host gate the HIGH flow keeps its goodput, with the
    legacy whole-link gate it collapses."""
    topo = topology.incast_fabric(4, host_gbps=100.0, uplink_gbps=800.0)
    flows = [Flow(src=f"h0_{i}", dst="h1_0", qos=QoS.LOW, tag="bulk")
             for i in range(3)]
    # HIGH fits inside the squeezed drain budget: only the *pause gate*
    # (not the drain) can hurt it
    flows.append(Flow(src="h0_3", dst="h1_0", offered_gbps=1.0,
                      qos=QoS.HIGH, tag="hi"))
    res = {}
    for per_tc in (False, True):
        def recv(host, per_tc=per_tc):
            # rnic_ecn_cnp off: the only receiver-side brake is the PFC
            # gate, whose granularity is exactly what's under test
            return S.testbed_100g("ddio", pfc_enabled=True,
                                  host_pfc_per_tc=per_tc,
                                  rnic_ecn_cnp=False,
                                  cpu_membw_gbps=1995.0)
        fc = FabricConfig(sim_time_s=0.004,
                          switch=SwitchConfig(pfc_enabled=True),
                          receiver_cfg=recv)
        sc = SC.Scenario(name=f"htc{per_tc}", topology=topo, flows=flows,
                         fabric=fc)
        res[per_tc] = sc.run()
        # both gate flavours agree scalar-vs-vector
        out = run_fabric_sweep([sc], backend="numpy")
        _, gp, _ = (res[per_tc],
                    np.array([res[per_tc].flow_goodput_gbps[f]
                              for f in range(len(flows))]), None)
        assert _maxrel(out["flow_goodput_gbps"][0], gp) <= 1e-9
    # per-TC: HIGH rides its own unpaused class at the full offered
    # rate; legacy: the whole-link gate strands HIGH behind multi-ms
    # pause dwells (the lossless fabric eventually delivers the backlog,
    # so the goodput gap is the stranded tail — the latency damage is
    # the duty cycle itself)
    assert res[True].tagged_goodput("hi") >= 0.95
    assert res[False].tagged_goodput("hi") <= 0.85
    assert res[True].tagged_goodput("hi") >= \
        1.25 * res[False].tagged_goodput("hi")


def test_host_per_tc_requires_classed_switch():
    """The per-class receiver gate needs classes on the wire: combining
    it with the legacy single-queue switch is rejected by both engines
    instead of silently diverging."""
    topo = topology.incast_fabric(2)
    flows = [Flow(src="h0_0", dst="h1_0")]
    fc = FabricConfig(sim_time_s=0.001,
                      switch=SwitchConfig(pfc_enabled=True, per_tc=False),
                      receiver_cfg=lambda h: S.testbed_100g(
                          "ddio", pfc_enabled=True, host_pfc_per_tc=True))
    with pytest.raises(ValueError, match="per_tc"):
        run_fabric(topo, flows, fc)
    sc = SC.Scenario(name="bad", topology=topo, flows=flows, fabric=fc)
    with pytest.raises(ValueError, match="per_tc"):
        FabricSweepParams.from_scenarios([sc])


def test_host_per_tc_default_off_and_partition_semantics():
    """The flag defaults off (legacy numerics untouched — the golden
    tests above pin that); when on, the watermark runs against the
    class's 1/N_QOS partition, so single-class traffic pauses no later
    (and usually earlier) than the whole-buffer gate."""
    assert S.SimConfig().host_pfc_per_tc is False
    a = S.run_sim(S.testbed_100g("ddio", sim_time_s=0.003,
                                 pfc_enabled=True))
    b = S.run_sim(S.testbed_100g("ddio", sim_time_s=0.003,
                                 pfc_enabled=True, host_pfc_per_tc=True))
    assert b.pfc_pause_us >= a.pfc_pause_us
    assert b.dropped_bytes <= a.dropped_bytes


def test_host_per_tc_gate_stays_lossless():
    """Regression: watermarks on fractions of the *shared* buffer would
    assert too late and drop; the partitioned watermarks keep the
    per-class gate as lossless as the legacy whole-link gate under a
    multi-class incast."""
    topo = topology.incast_fabric(9, host_gbps=100.0, uplink_gbps=800.0)
    flows = [Flow(src=f"h0_{i}", dst="h1_0", qos=QoS(i % 3), tag="t")
             for i in range(9)]
    for per_tc in (False, True):
        def recv(host, per_tc=per_tc):
            return S.testbed_100g("ddio", pfc_enabled=True,
                                  host_pfc_per_tc=per_tc,
                                  rnic_ecn_cnp=False,
                                  cpu_membw_gbps=1995.0)
        fc = FabricConfig(sim_time_s=0.005,
                          switch=SwitchConfig(pfc_enabled=True),
                          receiver_cfg=recv)
        r = run_fabric(topo, flows, fc)
        assert r.per_host["h1_0"].dropped_bytes == 0, per_tc


# --------------------------------------------------------------------------- #
# satellite: multi-receiver OLAP shuffle scenario
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_olap_shuffle_multi_receiver():
    sc = SC.olap_shuffle(n_mappers=3, n_reducers=3, shuffle_mb=0.6,
                         sim_time_s=0.006)
    assert len(sc.flows) == 9
    r = sc.run()
    assert len(r.per_host) == 3                   # every reducer reports
    done = [r.flow_completion_us[f] for f in range(9)]
    assert all(math.isfinite(c) for c in done)
    out = run_fabric_sweep([sc], backend="numpy")
    cp = np.array(done)
    assert _maxrel(out["flow_completion_us"][0], cp) <= 1e-9


def test_olap_shuffle_weighted_beats_static_hash_skew():
    """The shuffle's flow-id hash piles partitions onto one uplink;
    load-aware modes finish no later and balance the uplinks better."""
    res = {}
    for mode in ("static_ecmp", "weighted_ecmp"):
        r = SC.olap_shuffle(n_mappers=4, n_reducers=3, shuffle_mb=1.2,
                            routing=mode, sim_time_s=0.01).run()
        done = [r.flow_completion_us[f] for f in range(12)]
        assert all(math.isfinite(c) for c in done), mode
        res[mode] = (max(done), r.uplink_imbalance())
    assert res["weighted_ecmp"][0] <= res["static_ecmp"][0] * 1.05
    assert res["weighted_ecmp"][1] <= res["static_ecmp"][1] + 1e-9


# --------------------------------------------------------------------------- #
# satellite: metrics NaN-safety + sweep-structure validation
# --------------------------------------------------------------------------- #
def test_uplink_metrics_nan_safe():
    # spineless testbed: no uplinks -> empty util, imbalance 0.0, no NaN
    r = SC.single_pair("ddio", sim_time_s=0.002).run()
    assert r.uplink_util == {}
    assert r.uplink_imbalance() == 0.0
    assert r.reroute_count == 0
    out = run_fabric_sweep([SC.single_pair("ddio", sim_time_s=0.002)],
                           backend="numpy")
    assert out["reroute_count"][0] == 0


def test_dynamic_grid_structure_checks():
    a = SC.link_failure_incast(n_senders=2, sim_time_s=0.002)
    b = SC.link_failure_incast(n_senders=4, sim_time_s=0.002)
    with pytest.raises(ValueError):               # flow sets differ
        FabricSweepParams.from_scenarios([a, b])
    c = SC.link_failure_incast(n_senders=2, sim_time_s=0.002,
                               uplink_gbps=200.0)
    # same structure, different rates: allowed (per-point numeric)
    fsp = FabricSweepParams.from_scenarios([a, c])
    assert fsp.dyn_route and fsp.n_spines == 2
    # a static grid keeps the frozen-route structure
    fsp2 = FabricSweepParams.from_scenarios(
        [SC.incast(n_senders=2, sim_time_s=0.002)])
    assert not fsp2.dyn_route and fsp2.init_spine is None
