"""Validate the receive-datapath simulator against the paper's claims
(DESIGN.md table C1-C7).  Bands are deliberately generous — the simulator is
calibrated, not fitted point-wise."""
import pytest

from repro.core import simulator as S


@pytest.fixture(scope="module")
def sweep():
    out = {}
    for name, mk in (("100g", S.testbed_100g), ("25g", S.testbed_25g)):
        for msg_kb in (64, 256, 1024):
            for mode in ("ddio", "jet"):
                out[(name, msg_kb, mode)] = S.run_sim(
                    mk(mode, msg_bytes=msg_kb << 10, sim_time_s=0.02))
    return out


def test_c1_throughput_drop_64k_to_1m(sweep):
    """Paper fig 2: ~43% throughput drop at 1 MB vs 64 KB under membw
    contention (both testbeds)."""
    for bed in ("100g", "25g"):
        b64 = sweep[(bed, 64, "ddio")].goodput_gbps
        b1m = sweep[(bed, 1024, "ddio")].goodput_gbps
        drop = 1 - b1m / b64
        assert 0.30 < drop < 0.55, (bed, drop)


def test_c2_latency_grows_order_of_magnitude(sweep):
    """Paper fig 2c: avg latency grows ~10-25x from 64 KB to 1 MB."""
    for bed in ("100g", "25g"):
        r = (sweep[(bed, 1024, "ddio")].avg_latency_us /
             sweep[(bed, 64, "ddio")].avg_latency_us)
        assert r > 5.0, (bed, r)


def test_c3_ddio_miss_rate_leaky_dma(sweep):
    """Paper fig 3b: miss rate ~0 at 64 KB, 100% at 1 MB."""
    for bed in ("100g", "25g"):
        assert sweep[(bed, 64, "ddio")].ddio_miss_rate < 0.1
        assert sweep[(bed, 1024, "ddio")].ddio_miss_rate > 0.95


def test_c3b_doubling_ddio_does_not_help():
    """Paper §6: even 2x DDIO ways keep the throughput drop at 1 MB."""
    base = S.run_sim(S.testbed_100g("ddio", msg_bytes=1 << 20,
                                    sim_time_s=0.02))
    doubled = S.run_sim(S.testbed_100g("ddio", msg_bytes=1 << 20,
                                       sim_time_s=0.02,
                                       ddio_bytes=12 << 20))
    assert doubled.goodput_gbps < 1.15 * base.goodput_gbps


def test_c4_jet_throughput_gain(sweep):
    """Paper figs 6a/7a: Jet >=1.5x baseline at 256 KB; PFC/CNP ~ 0."""
    for bed in ("100g", "25g"):
        jet = sweep[(bed, 256, "jet")]
        base = sweep[(bed, 256, "ddio")]
        assert jet.goodput_gbps / base.goodput_gbps > 1.5, bed
        assert jet.pfc_pause_us == 0
        assert jet.cnp_count <= base.cnp_count
    # and Jet holds line rate
    assert sweep[("100g", 1024, "jet")].goodput_gbps > 195


def test_c5_latency_improvement(sweep):
    """Paper figs 6b/7b: Jet improves avg latency substantially."""
    for bed in ("100g", "25g"):
        jet = sweep[(bed, 256, "jet")].avg_latency_us
        base = sweep[(bed, 256, "ddio")].avg_latency_us
        assert jet < 0.65 * base, (bed, jet, base)


def test_c6_concurrency_window_saturation():
    """Paper fig 5: ~4 concurrent READs saturate 2x100G; 32 is safe."""
    # model: per-READ bandwidth-delay product limits throughput
    rtt_us, frag = 30.0, 256 << 10
    for conc, expect_full in ((1, False), (4, True), (32, True)):
        bw = min(200.0, conc * frag * 8 / (rtt_us * 1e-6) / 1e9)
        achieved = S.run_sim(S.testbed_100g(
            "jet", msg_bytes=frag, sim_time_s=0.01,
            offered_gbps=bw)).goodput_gbps
        assert (achieved > 190) == expect_full, (conc, achieved)


def test_c7_pool_and_escape_budget(sweep):
    """Paper §4.3/fig 10-11: 12 MB pool suffices; escape membw < 1 GB/s
    (8 Gbps); pool peak well under capacity."""
    jet = sweep[("100g", 256, "jet")]
    assert jet.pool_peak_bytes < 12 << 20
    assert jet.escape_dram_gbps < 8.0
    assert jet.nic_dram_gbps < 0.2 * sweep[("100g", 256,
                                            "ddio")].nic_dram_gbps + 1.0


def test_jet_under_extreme_pressure_engages_escape():
    """Shrunken pool + heavy stragglers must walk the full ladder without
    deadlock, and ECN backpressure must throttle the sender."""
    r = S.run_sim(S.testbed_100g("jet", msg_bytes=256 << 10,
                                 sim_time_s=0.12, jet_pool_bytes=2 << 20,
                                 straggler_frac=0.3, straggler_mult=100.0))
    assert r.escape_replaces > 0                       # rung 1 engaged
    assert r.escape_ecn > 0                            # rung 3 engaged
    assert r.pool_peak_bytes <= 2 << 20                # pool never overflows
    assert r.goodput_gbps > 0.1                        # no deadlock
    # ECN backpressure throttles the sender far below line rate (the pool
    # is 22x over-committed by straggler mass — protection is the point)
    assert r.goodput_gbps < 50.0
