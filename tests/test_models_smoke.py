"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes + finiteness (assignment
requirement), plus decode-path consistency (prefill+decode == forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeConfig, tiny_config
from repro.models import api
from repro.models.transformer import layer_kinds, segments
from repro.parallel.sharding import single_device_ctx

CTX = single_device_ctx(moe_capacity_factor=4.0)
SHAPE = ShapeConfig("smoke", "train", 32, 2)


@pytest.fixture(scope="module")
def tiny_setups():
    out = {}
    key = jax.random.key(0)
    for name, arch in ARCHS.items():
        cfg = tiny_config(arch)
        params = api.init_params(cfg, key)
        batch = api.synthetic_inputs(cfg, SHAPE, key, dtype=jnp.float32)
        out[name] = (cfg, params, batch)
    return out


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finite(tiny_setups, name):
    cfg, params, batch = tiny_setups[name]
    logits, aux = jax.jit(
        lambda p, b: api.forward(p, cfg, CTX, b["tokens"],
                                 b.get("patches"),
                                 compute_dtype=jnp.float32))(params, batch)
    b, t = batch["targets"].shape
    assert logits.shape == (b, t, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_finite_loss(tiny_setups, name):
    cfg, params, batch = tiny_setups[name]
    loss, metrics = jax.jit(
        lambda p, b: api.loss_fn(p, cfg, CTX, b,
                                 compute_dtype=jnp.float32))(params, batch)
    assert np.isfinite(float(loss))
    if cfg.num_experts:
        assert float(metrics["overflow"]) < 0.6


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_layer_structure_covers_config(name):
    arch = ARCHS[name]
    kinds = layer_kinds(arch)
    assert len(kinds) == arch.num_layers
    pattern, n_units, rem = segments(arch)
    assert n_units * len(pattern) + len(rem) == arch.num_layers
    if arch.num_experts:
        n_moe = sum(k == "attn_moe" for k in kinds)
        assert n_moe == sum(arch.is_moe_layer(i)
                            for i in range(arch.num_layers))
    if arch.attn_every:
        assert "mamba_attn" in kinds
    if arch.slstm_every:
        assert kinds.count("slstm") == arch.num_layers // arch.slstm_every
    if arch.cross_attn_every:
        assert kinds.count("attn_cross") == \
            arch.num_layers // arch.cross_attn_every


@pytest.mark.slow
@pytest.mark.parametrize("name", ["h2o-danube-1.8b", "zamba2-1.2b",
                                  "xlstm-125m", "gemma-7b",
                                  "musicgen-large"])
def test_prefill_decode_matches_forward(tiny_setups, name):
    """Greedy next-token from (prefill, then decode_step) must equal
    argmax of the full forward logits at successive positions."""
    cfg, params, batch = tiny_setups[name]
    toks = batch["tokens"][:1]          # single sequence
    t = toks.shape[-1]
    patches = batch["patches"][:1] if "patches" in batch else None
    logits_full, _ = api.forward(params, cfg, CTX, toks, patches,
                                 compute_dtype=jnp.float32)
    lg_pf, state, lengths = api.prefill(params, cfg, CTX, toks, patches,
                                        max_len=t + 4,
                                        compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg_pf),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    # one decode step fed with the last prompt token's argmax
    tok = (jnp.argmax(lg_pf, -1).astype(jnp.int32))
    if cfg.num_codebooks:
        tok = jnp.tile(tok[:, None], (1, cfg.num_codebooks))
    lg_dec, _ = api.decode_step(params, cfg, CTX, state, tok, lengths,
                                compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(lg_dec)).all()


@pytest.mark.slow
def test_swa_ring_buffer_decode_matches_window_attention():
    """Danube with a tiny window: decoding past the window must equal
    attention over only the last `window` tokens."""
    cfg = tiny_config(ARCHS["h2o-danube-1.8b"])
    assert cfg.sliding_window == 64
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=8, num_layers=2)
    key = jax.random.key(1)
    params = api.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab_size, jnp.int32)
    # decode from scratch, token by token
    state = api.init_decode_state(cfg, 1, 8, jnp.float32)
    lengths = jnp.zeros((1,), jnp.int32)
    outs = []
    for i in range(24):
        lg, state = api.decode_step(params, cfg, CTX, state, toks[:, i],
                                    lengths, compute_dtype=jnp.float32)
        lengths = lengths + 1
        outs.append(lg)
    # full forward with window masking
    logits_full, _ = api.forward(params, cfg, CTX, toks,
                                 compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(outs[-1][0]),
                               np.asarray(logits_full[0, -1]),
                               rtol=5e-3, atol=5e-3)
