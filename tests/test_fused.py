"""Fused tick stages + adaptive time-stepping: correctness contracts.

The contract under test (ISSUE 8 acceptance):

* the fused priority water-fills (``priority_grants`` /
  ``priority_admit``) are bit-identical between the inline ref tier and
  the Pallas kernel run under the interpreter (float32), and the whole
  jax engine with ``impl="interpret"`` reproduces ``impl="ref"`` output
  arrays exactly;
* ``adaptive_dt=False`` (the default) traces none of the adaptive
  machinery — the numpy reference stays bit-equal to the PR 5/7 frozen
  goldens already enforced by ``test_pfc_priority`` (re-asserted here on
  one golden directly);
* adaptive stepping honors the documented equivalence bound: per-flow
  delivered bytes within ``AdaptiveConfig.rel_bytes_bound`` of the
  fine-tick reference and completion timestamps quantized by at most
  ``(max_stride + 1) * dt`` per crossed macro window (hypothesis
  property over scenario shapes);
* macro-ticks genuinely fire on quiet-tailed grids (the stride loop
  takes measurably fewer iterations than ticks);
* the vectorized PFC-deadlock watchdog agrees with the scalar
  ``has_pause_cycle`` — exactly on synthetic pause masks (including
  cyclic and split-TC cases) and end to end on a faulted PFC grid.
"""
import dataclasses
import math
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.datapath import N_QOS
from repro.fabric import fused
from repro.fabric import scenarios as SC
from repro.fabric import vector as V
from repro.fabric.faults import FaultConfig, has_pause_cycle
from repro.fabric.fused import (AdaptiveConfig, cycle_flags,
                                pause_pair_onehot, priority_admit,
                                priority_grants)
from repro.fabric.vector import FabricSweepParams, run_fabric_sweep

EXAMPLES = int(os.environ.get("FABRIC_TEST_EXAMPLES", "2"))


# --------------------------------------------------------------------------- #
# fused water-fill kernels: unit + tier equivalence
# --------------------------------------------------------------------------- #
def _rand_fill(seed, g=3, n=7):
    rng = np.random.default_rng(seed)
    demand = rng.uniform(0.0, 4.0, (g, N_QOS, n)).astype(np.float32)
    can = (rng.random((g, N_QOS, n)) < 0.7).astype(np.float32)
    budget = rng.uniform(0.0, 6.0, (g, n)).astype(np.float32)
    crumb = np.full((g, n), 1e-3, np.float32)
    return demand, can, budget, crumb


def test_priority_grants_ref_is_strict_priority():
    demand, can, budget, crumb = _rand_fill(0, g=1)
    out = priority_grants(np, demand, can, budget, crumb,
                          np.float32(1.0), np.float32(0.0))
    # python re-derivation, one (class, port) at a time
    for j in range(demand.shape[-1]):
        left = budget[0, j]
        for q in range(N_QOS):
            d = demand[0, q, j]
            want = 0.0
            if can[0, q, j] > 0.5:
                want = min(1.0, left / (d if d > 0.0 else 1.0))
            assert out[0, q, j] == np.float32(want)
            left = left - np.float32(want) * d
            if left < crumb[0, j]:
                left = np.float32(0.0)


def test_priority_admit_ref_water_fills():
    demand, _, budget, _ = _rand_fill(1, g=1)
    out = priority_admit(np, demand, budget)
    for j in range(demand.shape[-1]):
        sp = budget[0, j]
        for q in range(N_QOS):
            want = min(demand[0, q, j], sp)
            assert out[0, q, j] == np.float32(want)
            sp = sp - want
    assert (out.sum(-2) <= budget + 1e-5).all()


def test_fused_kernels_interpret_matches_ref_bitwise():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    demand, can, budget, crumb = _rand_fill(2)
    ref_g = priority_grants(np, demand, can, budget, crumb,
                            np.float32(1.0), np.float32(0.0))
    int_g = priority_grants(jnp, jnp.asarray(demand), jnp.asarray(can),
                            jnp.asarray(budget), jnp.asarray(crumb),
                            jnp.float32(1.0), jnp.float32(0.0),
                            impl="interpret")
    assert np.array_equal(ref_g, np.asarray(int_g))
    ref_a = priority_admit(np, demand, budget)
    int_a = priority_admit(jnp, jnp.asarray(demand),
                           jnp.asarray(budget), impl="interpret")
    assert np.array_equal(ref_a, np.asarray(int_a))


def test_resolve_impl():
    assert fused.resolve_impl("ref") == "ref"
    assert fused.resolve_impl("interpret") == "interpret"
    with pytest.raises(ValueError):
        fused.resolve_impl("nope")


# --------------------------------------------------------------------------- #
# engine-level: interpret tier == ref tier, adaptive off == frozen golden
# --------------------------------------------------------------------------- #
def _incast_grid(sim_s=0.002, burst_mb=0.5, n=4, with_victim=True):
    return [SC.incast(n, mode=m, burst_mb=burst_mb, sim_time_s=sim_s,
                      pfc=p, with_victim=with_victim)
            for m in ("jet", "ddio") for p in (False, True)]


@pytest.mark.slow
def test_jax_interpret_tier_matches_ref_tier_exactly():
    pytest.importorskip("jax")
    scens = _incast_grid()
    ref = run_fabric_sweep(scens, backend="jax", impl="ref")
    itp = run_fabric_sweep(scens, backend="jax", impl="interpret")
    for k in ("flow_delivered_bytes", "flow_completion_us",
              "flow_goodput_gbps", "pause_total_us",
              "ecn_marked_bytes", "recv_goodput_gbps"):
        a, b = np.asarray(ref[k]), np.asarray(itp[k])
        both_nan = np.isnan(a) & np.isnan(b)
        assert (both_nan | (a == b)).all(), k


@pytest.mark.slow
def test_adaptive_off_stays_on_golden():
    # the frozen PR 5/7 golden (test_pfc_priority.GOLDEN) through the
    # public API with adaptive_dt explicitly False: bit-for-bit the
    # pre-adaptive numpy reference (goodput within the established
    # 1e-13 float64 envelope of the scalar golden literals)
    from test_pfc_priority import GOLDEN

    sc = SC.incast(n_senders=8, mode="jet", pfc=True, burst_mb=1.0,
                   sim_time_s=0.015)
    out = run_fabric_sweep([sc], backend="numpy", adaptive_dt=False)
    g = np.array(GOLDEN["incast8_jet_pfc"]["goodput"])
    got = out["flow_goodput_gbps"][0]
    rel = np.abs(got - g) / np.maximum(np.abs(g), 1e-30)
    assert rel.max() <= 1e-13
    comp = GOLDEN["incast8_jet_pfc"]["completion"]
    got_c = out["flow_completion_us"][0]
    for f, want in enumerate(comp):
        if math.isinf(want):
            assert math.isinf(got_c[f])
        else:
            assert abs(got_c[f] - want) <= 5e-13 * max(want, 1.0)


# --------------------------------------------------------------------------- #
# adaptive dt: equivalence bound + the machinery actually coarsens
# --------------------------------------------------------------------------- #
def _adaptive_iteration_count(scens, cfg):
    """Run the numpy adaptive loop by hand, returning (iters, ticks,
    results)."""
    fsp = FabricSweepParams.from_scenarios(scens)
    p = V._np_params(fsp, np.float64)
    st = V._static(fsp, np, np.float64)

    def ring_set(ring, idx, v):
        ring[..., idx, :, :] = v
        return ring

    step = V._make_step(np, ring_set, st, p, fsp.dt_us, fsp.ring_len,
                        np.float64, fsp.cnp_ring, V._opts(fsp))
    stride = fused.make_stride_fn(np, fsp, p, V._opts(fsp), cfg,
                                  np.float64)
    s = V._init_state(np, (fsp.n_points,), fsp, p, np.float64)
    t = it = 0
    while t < fsp.ticks:
        s1 = step(s, np.int32(t), np.int32(it))
        k = int(stride(s, s1, np.int32(t)))
        if k > 1:
            s1 = fused.macro_advance(np, s, s1, np.float64(k - 1))
        s, t, it = s1, t + k, it + 1
    return it, fsp.ticks, V._results(s, fsp)


def test_adaptive_coarsens_and_bounds_delivered():
    # a drain-bounded grid (every burst finite): the incast drains,
    # the tail is genuinely quiet, and the stride machinery must
    # exploit it.  Open victim flows sit in a permanent DCQCN
    # sawtooth — per-tick dynamics the stride correctly refuses to
    # coarsen (covered by the bound tests below)
    scens = _incast_grid(with_victim=False)
    cfg = AdaptiveConfig()
    iters, ticks, adap = _adaptive_iteration_count(scens, cfg)
    assert iters < ticks * 0.5, (iters, ticks)
    fine = run_fabric_sweep(scens, backend="numpy")
    db_f = fine["flow_delivered_bytes"]
    db_a = adap["flow_delivered_bytes"]
    rel = np.abs(db_a - db_f) / np.maximum(db_f, 1.0)
    assert rel.max() <= cfg.rel_bytes_bound, rel.max()


@pytest.mark.slow
def test_adaptive_public_api_matches_hand_loop():
    scens = _incast_grid()
    via_api = run_fabric_sweep(scens, backend="numpy", adaptive_dt=True)
    _, _, by_hand = _adaptive_iteration_count(scens, AdaptiveConfig())
    for k in ("flow_delivered_bytes", "flow_completion_us"):
        a, b = np.asarray(via_api[k]), np.asarray(by_hand[k])
        both_nan = np.isnan(a) & np.isnan(b)
        assert (both_nan | (a == b)).all(), k


@pytest.mark.slow
def test_adaptive_jax_within_bound():
    pytest.importorskip("jax")
    scens = _incast_grid()
    cfg = AdaptiveConfig()
    fine = run_fabric_sweep(scens, backend="numpy")
    ja = run_fabric_sweep(scens, backend="jax", adaptive_dt=True)
    db_f = fine["flow_delivered_bytes"]
    rel = np.abs(ja["flow_delivered_bytes"] - db_f) \
        / np.maximum(db_f, 1.0)
    # documented bound + the engine's float32 slack
    assert rel.max() <= cfg.rel_bytes_bound + 5e-4, rel.max()


def test_adaptive_disabled_by_onoff_trains():
    # on/off burst trains have no closed form: stride stays 1 and the
    # result is bit-equal to the fine reference
    scens = [SC.incast(2, mode="jet", burst_mb=0.25, sim_time_s=0.001)]
    for f in scens[0].flows:
        f.on_off_us = (20.0, 20.0)
    iters, ticks, adap = _adaptive_iteration_count(scens,
                                                   AdaptiveConfig())
    assert iters == ticks
    fine = run_fabric_sweep(scens, backend="numpy")
    assert np.array_equal(adap["flow_delivered_bytes"],
                          fine["flow_delivered_bytes"])


@pytest.mark.slow
@settings(max_examples=EXAMPLES, deadline=None)
@given(n=st.integers(2, 5), jet=st.booleans(), pfc=st.booleans(),
       burst_q=st.integers(1, 4))
def test_adaptive_equivalence_bound_property(n, jet, pfc, burst_q):
    """Hypothesis property: coarsening never moves delivered bytes
    beyond ``rel_bytes_bound`` nor completion stamps beyond the macro
    quantization envelope."""
    cfg = AdaptiveConfig()
    scens = [SC.incast(n, mode="jet" if jet else "ddio",
                       burst_mb=0.25 * burst_q, sim_time_s=0.002,
                       pfc=pfc)]
    fine = run_fabric_sweep(scens, backend="numpy")
    adap = run_fabric_sweep(scens, backend="numpy", adaptive_dt=True,
                            adaptive=cfg)
    db_f = fine["flow_delivered_bytes"]
    rel = np.abs(adap["flow_delivered_bytes"] - db_f) \
        / np.maximum(db_f, 1.0)
    assert rel.max() <= cfg.rel_bytes_bound, rel.max()
    cf = fine["flow_completion_us"]
    ca = adap["flow_completion_us"]
    fin = np.isfinite(cf)
    assert (np.isfinite(ca) == fin).all()
    if fin.any():
        dt = 1.0  # incast grids pack dt_us = 1.0
        shift = np.abs(ca[fin] - cf[fin]).max()
        # (max_stride + 1) * dt per crossed macro window; allow the
        # delivered-byte drift to compound across a few windows
        assert shift <= 4 * (cfg.max_stride + 1) * dt, shift


# --------------------------------------------------------------------------- #
# PFC-deadlock watchdog: synthetic + engine equivalence
# --------------------------------------------------------------------------- #
def test_cycle_flags_matches_has_pause_cycle_synthetic():
    port_keys = [("a", "b"), ("b", "c"), ("c", "a"), ("b", "a")]
    E = pause_pair_onehot(port_keys)
    n = 3
    cases = [
        {(0, 0), (1, 0), (2, 0)},       # 3-cycle in one TC -> deadlock
        {(0, 0), (1, 0)},               # open chain -> no
        {(0, 0), (1, 1), (2, 2)},       # same edges split across TCs
        set(),                          # nothing paused
        {(0, 1), (3, 1)},               # a<->b ping-pong, one class
        {(0, 0), (3, 1)},               # ping-pong split across TCs
    ]
    for case in cases:
        lp = np.zeros((2, N_QOS, len(port_keys)))
        pairs = []
        for pi, tc in case:
            lp[0, tc, pi] = 1.0
            pairs.append((port_keys[pi], tc))
        want = has_pause_cycle(pairs)
        got = cycle_flags(np, lp, E, n, 1.0)
        assert bool(got[0]) == want, case
        assert not bool(got[1])         # the all-zero point never flags


@pytest.mark.slow
def test_deadlock_ticks_scalar_vs_numpy_engine():
    base = SC.all_to_all(4, mode="ddio", msg_kb=256, pfc=True,
                         sim_time_s=0.002)
    scens = []
    for _ in range(2):
        sc = dataclasses.replace(base)
        sc.fabric = dataclasses.replace(base.fabric)
        sc.fabric.faults = FaultConfig()
        scens.append(sc)
    out = run_fabric_sweep(scens, backend="numpy")
    for i, sc in enumerate(scens):
        r = sc.run()
        assert float(r.deadlock_ticks) == float(out["deadlock_ticks"][i])


@pytest.mark.slow
def test_deadlock_ticks_jax_matches_numpy():
    pytest.importorskip("jax")
    sc = SC.incast(4, mode="ddio", burst_mb=1.0, sim_time_s=0.002,
                   pfc=True)
    sc.fabric.faults = FaultConfig()
    out_np = run_fabric_sweep([sc], backend="numpy")
    out_jx = run_fabric_sweep([sc], backend="jax")
    assert float(out_np["deadlock_ticks"][0]) == \
        float(out_jx["deadlock_ticks"][0])
