"""Fabric subsystem: topology invariants, switch ECN/PFC mechanics,
single-host equivalence with run_sim, vectorized-sweep agreement, and the
fleet-level incast/HoL phenomenology the fabric exists to reproduce."""
import math

import numpy as np
import pytest

from repro.core import simulator as S
from repro.fabric import (FabricConfig, Flow, SwitchConfig, run_fabric,
                          scenarios, topology)
from repro.fabric.switch import OutputPort
from repro.fabric.sweep import grid_configs, run_sweep


# --------------------------------------------------------------------------- #
# topology
# --------------------------------------------------------------------------- #
def test_clos_invariants():
    topo = topology.clos(n_leaves=3, hosts_per_leaf=4, n_spines=2,
                         host_gbps=100.0, uplink_gbps=400.0)
    topo.validate()
    assert len(topo.hosts) == 12
    assert topo.bisection_gbps() == 3 * 2 * 400.0
    # 4x100 host-facing vs 2x400 spine-facing per leaf
    assert topo.oversubscription("leaf0") == pytest.approx(0.5)
    # every link has a reverse twin with equal capacity
    for (a, b), l in topo.links.items():
        assert topo.links[(b, a)].gbps == l.gbps


def test_routes_and_ecmp():
    topo = topology.clos(n_leaves=2, hosts_per_leaf=2, n_spines=2)
    # intra-leaf short-circuits the spine tier
    assert topo.route("h0_0", "h0_1", 0) == ["h0_0", "leaf0", "h0_1"]
    # cross-leaf transits exactly one spine; ECMP spreads by flow id
    r0 = topo.route("h0_0", "h1_0", 0)
    r1 = topo.route("h0_0", "h1_0", 1)
    assert len(r0) == 5 and r0[2] == "spine0" and r1[2] == "spine1"
    links = topo.route_links("h0_0", "h1_0", 0)
    assert [l.src for l in links] == ["h0_0", "leaf0", "spine0", "leaf1"]
    with pytest.raises(ValueError):
        topo.route("h0_0", "h0_0", 0)


def test_validate_catches_broken_topologies():
    topo = topology.clos(2, 2, 1)
    bad = topology.Topology(topo.hosts, topo.leaves, topo.spines,
                            dict(topo.links), dict(topo.host_leaf))
    del bad.links[("leaf0", "h0_0")]          # one-way access link
    with pytest.raises(ValueError):
        bad.validate()
    bad2 = topology.Topology(topo.hosts, topo.leaves, [], topo.links,
                             topo.host_leaf)
    with pytest.raises(ValueError):
        bad2.validate()                        # multi-leaf needs spines


# --------------------------------------------------------------------------- #
# switch mechanics
# --------------------------------------------------------------------------- #
def _port(**kw):
    cfg = SwitchConfig(port_buffer_bytes=1 << 20, **kw)
    return OutputPort(topology.Link("a", "b", 80.0), cfg)


def test_port_ecn_marks_past_knee():
    p = _port(ecn_kmin_frac=0.25)
    p.enqueue(0, 200 << 10, 0.0, None)          # queue was 0: unmarked
    assert p.marked_bytes == 0
    p.enqueue(0, 100 << 10, 0.0, None)          # queue 200 KB, still < knee
    assert p.marked_bytes == 0
    p.enqueue(0, 200 << 10, 0.0, None)          # queue 300 KB > 256 KB knee
    assert p.marked_bytes == pytest.approx(200 << 10)
    # drained bytes carry their marks out proportionally
    out = p.drain(10.0)                          # 80 Gbps * 10 us = 100 KB
    (fid, b, m) = out[0]
    assert fid == 0 and b == pytest.approx(1e5)
    assert 0.0 < m < b


def test_port_tail_drop_and_conservation():
    p = _port()
    lost = p.enqueue(0, 3 << 20, 0.0, None)      # 3 MB into a 1 MB buffer
    assert lost == pytest.approx(2 << 20)
    assert p.queued_bytes == pytest.approx(1 << 20)
    total_out = 0.0
    for _ in range(200):
        total_out += sum(b for _, b, _m in p.drain(10.0))
    assert total_out == pytest.approx(1 << 20)
    assert p.queued_bytes == pytest.approx(0.0, abs=1e-6)


def test_port_pfc_hysteresis():
    p = _port(pfc_enabled=True, pfc_xoff_frac=0.5, pfc_xon_frac=0.25)
    p.enqueue(7, 600 << 10, 0.0, ("x", "a"), tc=1)
    p.update_pfc()
    # pause is per (ingress link, traffic class): only TC 1 is targeted
    assert p.pause_asserted and p.pause_targets() == {(("x", "a"), 1)}
    assert p.tc_asserted == [False, True, False]
    # draining below xon releases the pause
    while p.queued_bytes > 0.25 * (1 << 20):
        p.drain(10.0)
    p.update_pfc()
    assert not p.pause_asserted and p.pause_targets() == set()


# --------------------------------------------------------------------------- #
# single-host fabric == run_sim (the acceptance anchor)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("mode", ["ddio", "jet"])
def test_single_pair_matches_run_sim(mode):
    ref = S.run_sim(S.testbed_100g(mode, sim_time_s=0.005))
    r = scenarios.single_pair(mode, sim_time_s=0.005).run()
    got = r.per_host["h0_1"]
    assert got.goodput_gbps == pytest.approx(ref.goodput_gbps, rel=0.05)
    # the refactor keeps them numerically identical, not merely close
    assert got.goodput_gbps == pytest.approx(ref.goodput_gbps, rel=1e-9)
    assert got.cnp_count == ref.cnp_count
    assert got.ddio_miss_rate == pytest.approx(ref.ddio_miss_rate)


# --------------------------------------------------------------------------- #
# vectorized sweep vs numpy reference vs run_sim
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def sweep_grid():
    cfgs, pts = grid_configs(
        S.testbed_100g, mode="ddio", sim_time_s=0.004,
        msg_bytes=[64 << 10, 256 << 10, 1 << 20],
        cpu_membw_gbps=[1200.0, 1500.0, 1760.0],
        ddio_bytes=[4 << 20, 6 << 20],
        num_qps=[16, 32])
    assert len(cfgs) >= 32                      # acceptance: >=32-point grid
    return cfgs


def test_sweep_vectorized_matches_numpy(sweep_grid):
    ref = run_sweep(sweep_grid, backend="numpy")
    got = run_sweep(sweep_grid, backend="jax")
    for key in ("goodput_gbps", "cnp_count", "ddio_miss_rate",
                "pfc_pause_us", "dropped_bytes"):
        a, b = got[key], ref[key]
        assert np.all(np.abs(a - b) <= 0.01 * np.abs(b) + 1e-6), key


def test_sweep_numpy_matches_run_sim(sweep_grid):
    sample = sweep_grid[::8]
    seq = np.array([S.run_sim(c).goodput_gbps for c in sample])
    ref = run_sweep(list(sample), backend="numpy")["goodput_gbps"]
    assert np.all(np.abs(ref - seq) <= 0.01 * seq + 1e-6)


def test_sweep_jet_escape_ladder():
    cfgs, _ = grid_configs(
        S.testbed_100g, mode="jet", sim_time_s=0.004,
        jet_pool_bytes=[2 << 20, 12 << 20],
        straggler_frac=[0.005, 0.3])
    out_np = run_sweep(cfgs, backend="numpy")
    out_jx = run_sweep(cfgs, backend="jax")
    # the tight-pool/heavy-straggler corner must engage the ladder...
    assert out_np["escape_replaces"].max() > 0
    # ...identically in both backends
    for key in ("escape_replaces", "escape_copies", "escape_ecn"):
        np.testing.assert_allclose(out_jx[key], out_np[key])


def test_sweep_rejects_mixed_timebases():
    cfgs = [S.testbed_100g("jet", sim_time_s=0.004),
            S.testbed_100g("jet", sim_time_s=0.008)]
    with pytest.raises(ValueError):
        run_sweep(cfgs)


def test_sweep_unroll_is_a_pure_perf_knob(sweep_grid):
    """The scan unroll factor (autotuned by default, see fabric._scan)
    must never change results — same program, different loop shape."""
    sample = list(sweep_grid[::12])
    a = run_sweep(sample, backend="jax")          # unroll="auto"
    b = run_sweep(sample, backend="jax", unroll=4)
    for key in ("goodput_gbps", "cnp_count", "dropped_bytes"):
        np.testing.assert_allclose(a[key], b[key], rtol=1e-6)


# --------------------------------------------------------------------------- #
# incast / PFC phenomenology
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def incast_pair():
    lossy = scenarios.incast(n_senders=8, mode="ddio", pfc=False,
                             burst_mb=1.0, sim_time_s=0.015).run()
    pfc = scenarios.incast(n_senders=8, mode="ddio", pfc=True,
                           burst_mb=1.0, sim_time_s=0.015).run()
    return lossy, pfc


def test_incast_completion_grows_with_fanin():
    fct = []
    for n in (2, 8):
        r = scenarios.incast(n_senders=n, mode="ddio", pfc=False,
                             burst_mb=1.0, with_victim=False,
                             sim_time_s=0.02).run()
        assert math.isfinite(r.incast_completion_us), n
        fct.append(r.incast_completion_us)
    assert fct[1] > 1.5 * fct[0]


def test_pfc_is_lossless_but_spreads_pauses(incast_pair):
    lossy, pfc = incast_pair
    # lossy fabric drops at the congested leaf port, PFC does not
    assert lossy.switch_dropped_bytes > 0
    assert pfc.switch_dropped_bytes == 0
    assert lossy.pause_fanout == 0
    # pause frames propagate beyond the congested downlink
    assert pfc.pause_fanout >= 2
    assert sum(pfc.pause_link_us.values()) > 0


def test_pfc_head_of_line_blocks_victim(incast_pair):
    lossy, pfc = incast_pair
    # the victim shares only the source leaf with the incast, yet PFC
    # pauses collapse its goodput; the lossy fabric leaves it unharmed
    assert pfc.victim_goodput_gbps < 0.5 * lossy.victim_goodput_gbps
    assert lossy.victim_goodput_gbps > 20.0


def test_incast_receiver_results_per_host():
    r = scenarios.incast(n_senders=4, mode="jet", burst_mb=0.5,
                         sim_time_s=0.01).run()
    assert set(r.per_host) == {"h1_0", "h1_1"}
    assert r.per_host["h1_0"].goodput_gbps > 0
    # every incast flow completed and is accounted
    for fid, tag in r.flow_tags.items():
        if tag == "incast":
            assert math.isfinite(r.flow_completion_us[fid])
            assert r.flow_delivered_bytes[fid] >= 0.5e6 - 1e3
