"""Unit tests for the perf-variant machinery: accum microbatching math,
serving dtype selection, and the capacity audit."""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeConfig, tiny_config
from repro.models import api
from repro.optim import adamw
from repro.parallel.sharding import single_device_ctx
from repro.train import steps as steps_mod

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_accum_matches_full_batch_single_device():
    """Gradient accumulation equals the full-batch step bit-for-nearly."""
    cfg = dataclasses.replace(tiny_config(ARCHS["starcoder2-15b"]),
                              num_layers=2)
    opt_cfg = adamw.OptConfig(lr=1e-3)
    key = jax.random.key(0)
    batch = api.synthetic_inputs(cfg, ShapeConfig("t", "train", 32, 8),
                                 key, dtype=jnp.float32)
    ctx = single_device_ctx()
    s1, m1 = jax.jit(steps_mod.make_train_step(
        cfg, ctx, opt_cfg, jnp.float32))(
        steps_mod.init_state(cfg, opt_cfg, key), batch)
    micro = {k: v.reshape((4, 2) + v.shape[1:]) for k, v in batch.items()}
    s2, m2 = jax.jit(steps_mod.make_train_step(
        cfg, ctx, opt_cfg, jnp.float32, accum_steps=4))(
        steps_mod.init_state(cfg, opt_cfg, key), micro)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_accum_preserves_state_structure():
    cfg = dataclasses.replace(tiny_config(ARCHS["gemma-7b"]), num_layers=2)
    opt_cfg = adamw.OptConfig()
    key = jax.random.key(0)
    batch = api.synthetic_inputs(cfg, ShapeConfig("t", "train", 16, 4),
                                 key, dtype=jnp.float32)
    micro = {k: v.reshape((2, 2) + v.shape[1:]) for k, v in batch.items()}
    state = steps_mod.init_state(cfg, opt_cfg, key)
    new, _ = jax.jit(steps_mod.make_train_step(
        cfg, single_device_ctx(), opt_cfg, jnp.float32,
        accum_steps=2))(state, micro)
    assert jax.tree.structure(new) == jax.tree.structure(state)
    assert int(new["step"]) == 1


def test_compressed_pod_state_has_err_tree():
    cfg = dataclasses.replace(tiny_config(ARCHS["chatglm3-6b"]),
                              num_layers=2)
    opt_cfg = adamw.OptConfig(compressed_pod_grads=True)
    state = steps_mod.abstract_state(cfg, opt_cfg)
    assert "err" in state
    # err mirrors params shapes at bf16
    for p, e in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(state["err"])):
        assert p.shape == e.shape and e.dtype == jnp.bfloat16
    # and without the flag there is no err tree
    state2 = steps_mod.abstract_state(cfg, adamw.OptConfig())
    assert "err" not in state2


def test_serving_bf16_abstract_params():
    cfg = ARCHS["llama4-scout-17b-a16e"]
    p32 = api.abstract_params(cfg)
    p16 = api.abstract_params(cfg, jnp.bfloat16)
    a, b = jax.tree.leaves(p32)[0], jax.tree.leaves(p16)[0]
    assert a.dtype == jnp.float32 and b.dtype == jnp.bfloat16
    assert a.shape == b.shape


def test_capacity_audit_covers_all_cells():
    sys.path.insert(0, REPO)
    from benchmarks import capacity
    rows = capacity.run()
    if not rows:
        pytest.skip("dry-run artifacts not generated yet")
    assert len(rows) == 33
    # every over-budget cell has a concrete fitting strategy
    for r in rows:
        if not r["fits_16gb"]:
            assert r["strategy"] != "-", r
    # the big train cells exceed as-is (full activations) and are flagged
    by = {(r["arch"], r["shape"]): r for r in rows}
    for arch in ("llama4-maverick-400b-a17b", "llama4-scout-17b-a16e",
                 "starcoder2-15b"):
        assert not by[(arch, "train_4k")]["fits_16gb"]
    # small models fit everywhere
    assert by[("xlstm-125m", "train_4k")]["fits_16gb"]
