"""Serving engine: Jet admission, lane recycle, paged KV, correctness of
engine decode vs direct model decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, tiny_config
from repro.core.jet import JetConfig
from repro.core.pool import DevicePool
from repro.models import api
from repro.parallel.sharding import single_device_ctx
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.kv_cache import PagedKV, PagedKVConfig

CTX = single_device_ctx(moe_capacity_factor=4.0)


def _engine(lanes=2, max_len=64):
    cfg = dataclasses.replace(tiny_config(ARCHS["h2o-danube-1.8b"]),
                              num_layers=2, sliding_window=None)
    params = api.init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, EngineConfig(max_lanes=lanes, max_len=max_len,
                                          eos_token=-1),
                        params, CTX, JetConfig(pool_bytes=1 << 20))
    return cfg, params, eng


def test_engine_serves_all_requests():
    cfg, params, eng = _engine(lanes=2)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(i, rng.integers(
            2, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4))
    eng.run_until_done(max_ticks=100)
    assert len(eng.done) == 5
    assert all(len(r.generated) == 4 for r in eng.done.values())
    # lanes were recycled: 5 requests through 2 lanes
    assert eng.jet.stats()["live_transfers"] == 0


def test_engine_greedy_matches_direct_decode():
    """The engine's generated tokens must equal a direct prefill+decode."""
    cfg, params, eng = _engine(lanes=1)
    rng = np.random.default_rng(1)
    prompt = rng.integers(2, cfg.vocab_size, size=8).astype(np.int32)
    eng.submit(Request(0, prompt, max_new_tokens=3))
    eng.run_until_done(max_ticks=50)
    got = eng.done[0].generated

    logits, state, lengths = api.prefill(params, cfg, CTX,
                                         jnp.asarray(prompt)[None, :],
                                         max_len=64,
                                         compute_dtype=jnp.float32)
    want = [int(jnp.argmax(logits[0]))]
    tok = jnp.asarray([want[-1]], jnp.int32)
    for _ in range(2):
        lg, state = api.decode_step(params, cfg, CTX, state, tok, lengths,
                                    compute_dtype=jnp.float32)
        lengths = lengths + 1
        want.append(int(jnp.argmax(lg[0])))
        tok = jnp.asarray([want[-1]], jnp.int32)
    assert got == want


def test_engine_admission_respects_lanes():
    cfg, params, eng = _engine(lanes=1)
    rng = np.random.default_rng(2)
    for i in range(3):
        eng.submit(Request(i, rng.integers(
            2, cfg.vocab_size, size=4).astype(np.int32), max_new_tokens=6))
    eng.step()
    assert len(eng.active) == 1                 # one lane -> one active
    assert len(eng.waiting) == 2
    eng.run_until_done(max_ticks=60)
    assert len(eng.done) == 3


def test_engine_network_backpressure_gates_admission():
    """Fabric congestion (PFC pause / pool danger) routed into the engine
    must stall decode-lane admission without losing requests."""
    cfg, params, eng = _engine(lanes=2)
    rng = np.random.default_rng(3)
    for i in range(3):
        eng.submit(Request(i, rng.integers(
            2, cfg.vocab_size, size=4).astype(np.int32), max_new_tokens=4))
    eng.set_network_pressure(True)
    eng.step()
    eng.step()
    assert len(eng.active) == 0            # gate shut: nothing admitted
    assert len(eng.waiting) == 3
    assert eng.network_paused
    eng.set_network_pressure(False)        # xon: backlog clears
    eng.run_until_done(max_ticks=60)
    assert len(eng.done) == 3
    assert all(len(r.generated) == 4 for r in eng.done.values())


def test_paged_kv_append_release_cycle():
    cfg = PagedKVConfig(num_pages=8, page_size=4, num_kv_heads=2,
                        head_dim=8, max_pages_per_seq=3,
                        dtype=jnp.float32)
    kv = PagedKV.create(cfg, batch=2)
    k = jnp.ones((2, 8))
    ok_all = True
    for i in range(6):                          # 6 tokens -> 2 pages
        kv, ok = kv.append(0, k * i, k * i)
        ok_all &= bool(ok)
    assert ok_all
    assert int(kv.lengths[0]) == 6
    used = int(8 - kv.pool.available())
    assert used == 2
    kv = kv.release(0)
    assert int(kv.pool.available()) == 8        # swift recycle
    assert int(kv.lengths[0]) == 0


def test_paged_kv_pool_exhaustion_escape():
    cfg = PagedKVConfig(num_pages=1, page_size=2, num_kv_heads=1,
                        head_dim=4, max_pages_per_seq=2, dtype=jnp.float32)
    kv = PagedKV.create(cfg, batch=1)
    k = jnp.ones((1, 4))
    kv, ok1 = kv.append(0, k, k)
    kv, ok2 = kv.append(0, k, k)
    kv, ok3 = kv.append(0, k, k)                # needs a 2nd page -> escape
    assert bool(ok1) and bool(ok2)
    assert not bool(ok3)


def test_paged_decode_kernel_against_contiguous():
    """decode_attention over DevicePool-allocated pages == contiguous."""
    from repro.kernels import ops, ref
    rng = np.random.default_rng(3)
    pool = DevicePool.create(8)
    page, hkv, d, b = 4, 2, 16, 2
    kp = jnp.zeros((8, page, hkv, d))
    vp = jnp.zeros((8, page, hkv, d))
    table = np.full((b, 2), -1, np.int32)
    lengths = np.array([6, 3], np.int32)
    kc = np.zeros((b, 8, hkv, d), np.float32)
    vc = np.zeros((b, 8, hkv, d), np.float32)
    for i in range(b):
        need = -(-int(lengths[i]) // page)
        pool, idx, ok = pool.alloc(need)
        assert bool(ok)
        table[i, :need] = np.asarray(idx)[:need]
        for j in range(need):
            blk_k = rng.standard_normal((page, hkv, d)).astype(np.float32)
            blk_v = rng.standard_normal((page, hkv, d)).astype(np.float32)
            kp = kp.at[int(idx[j])].set(blk_k)
            vp = vp.at[int(idx[j])].set(blk_v)
            kc[i, j * page:(j + 1) * page] = blk_k
            vc[i, j * page:(j + 1) * page] = blk_v
    q = jnp.asarray(rng.standard_normal((b, 4, d)), jnp.float32)
    o_pag, lse_pag = ops.decode_attention(q, kp, vp, jnp.asarray(table),
                                          jnp.asarray(lengths),
                                          impl="interpret")
    o_ctg, lse_ctg = ref.decode_attention_naive(q, jnp.asarray(kc),
                                                jnp.asarray(vc),
                                                jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(o_pag), np.asarray(o_ctg),
                               rtol=2e-4, atol=2e-4)
