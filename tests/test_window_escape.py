"""Property tests: READ windows (paper §4.1.2) + escape ladder (§4.3)."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.escape import Action, EscapeConfig, EscapeController
from repro.core.pool import SlabPool
from repro.core.window import ReadWindow, fragment


def test_fragmentation_rule():
    # paper: slice into <=256 KB fragments
    frags = fragment(1_000_000)
    assert sum(frags) == 1_000_000
    assert all(f <= 256 << 10 for f in frags)
    assert frags[:-1] == [256 << 10] * (len(frags) - 1)
    with pytest.raises(ValueError):
        fragment(0)


@given(st.lists(st.tuples(st.integers(1, 256 << 10), st.booleans()),
                min_size=1, max_size=80))
@settings(max_examples=50, deadline=None)
def test_window_invariants(events):
    w = ReadWindow(max_concurrency=8, max_inflight_bytes=1 << 20)
    inflight_ids = []
    now = 0.0
    for nbytes, complete_one in events:
        now += 1.0
        w.submit(nbytes, now)
        admitted = w.pump(now)
        inflight_ids.extend(r.req_id for r in admitted)
        w.check_invariants()
        if complete_one and inflight_ids:
            w.complete(inflight_ids.pop(0))
            w.check_invariants()
    # FIFO: admitted ids are monotonically increasing
    assert inflight_ids == sorted(inflight_ids)


def test_window_concurrency_cap():
    w = ReadWindow(max_concurrency=4, max_inflight_bytes=100 << 20)
    for _ in range(10):
        w.submit(1024, 0.0)
    admitted = w.pump(0.0)
    assert len(admitted) == 4                       # concurrency window
    w.complete(admitted[0].req_id)
    assert len(w.pump(1.0)) == 1                    # window slides


def test_window_bytes_cap_and_aimd():
    w = ReadWindow(max_concurrency=32, max_inflight_bytes=1 << 20)
    for _ in range(8):
        w.submit(256 << 10, 0.0)
    assert len(w.pump(0.0)) == 4                    # 4 x 256KB = 1 MB
    cap0 = w.cap_bytes
    w.on_ecn()
    assert w.cap_bytes == cap0 // 2                 # multiplicative decrease
    for _ in range(1000):
        w.on_quiet()
    assert w.cap_bytes == cap0                      # additive recovery, capped


def _pressured_pool():
    pool = SlabPool(capacity_bytes=16 * 4096)
    ids_a = pool.alloc(0, 10 * 4096, now=0.0)
    ids_b = pool.alloc(1, 5 * 4096, now=10.0)
    return pool, ids_a, ids_b


def test_escape_ladder_none_when_healthy():
    pool = SlabPool(capacity_bytes=16 * 4096)
    pool.alloc(0, 4 * 4096, 0.0)
    esc = EscapeController(EscapeConfig(cache_safe=0.2, cache_danger=0.05))
    assert esc.step(pool, 1.0) == [(Action.NONE, None)]


def test_escape_ladder_replace_then_copy_then_ecn():
    cfg = EscapeConfig(cache_safe=0.5, cache_danger=0.4,
                       mem_esc_bytes=2 * 4096, credit=0.5,
                       straggler_age=1.0)
    esc = EscapeController(cfg)
    pool, ids_a, ids_b = _pressured_pool()
    # t=20: app0's slots (age 20) are stragglers; available 1/16 < safe
    acts = esc.step(pool, 20.0)
    kinds = [a for a, _ in acts]
    assert Action.REPLACE in kinds                 # rung 1
    assert pool.replace_mem_bytes == cfg.mem_esc_bytes
    # replace budget exhausted -> rung 2: copy app0 (100% stragglers)
    acts2 = esc.step(pool, 21.0)
    kinds2 = [a for a, _ in acts2]
    assert Action.COPY in kinds2
    assert esc.stats.bytes_copied > 0
    # app0's slots were evicted
    assert pool.held_slots(0) == 0


def test_escape_marks_ecn_under_danger():
    cfg = EscapeConfig(cache_safe=0.9, cache_danger=0.8,
                       mem_esc_bytes=0, credit=2.0,  # no replace, no copy
                       straggler_age=1e9)
    esc = EscapeController(cfg)
    pool = SlabPool(capacity_bytes=16 * 4096)
    pool.alloc(0, 15 * 4096, 0.0)
    acts = esc.step(pool, 1.0)
    assert (Action.MARK_ECN, None) in acts         # rung 3 (last resort)
