"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracles, plus hypothesis property checks on the online-softmax combine."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.jet_staged_matmul import staging_pool_bytes

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.float16 or \
        dtype == "bfloat16" else dict(rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (100, 130, 70),
                                   (256, 512, 128), (17, 65, 33)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_staged_matmul_sweep(m, k, n, dtype):
    a = jnp.asarray(RNG.normal(size=(m, k)), dtype=dtype)
    b = jnp.asarray(RNG.normal(size=(k, n)), dtype=dtype)
    out = ops.staged_matmul(a, b, impl="interpret", block_m=32, block_n=32,
                            block_k=64)
    want = ref.matmul_naive(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype != np.float32 else 1e-4,
                               atol=2e-2 if dtype != np.float32 else 1e-4)


def test_staging_pool_sizing():
    # the in-kernel pool must fit VMEM (~128 MB) with double buffering
    assert staging_pool_bytes(256, 256, 512) < 16 << 20


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("t,s,window", [(16, 16, None), (16, 16, 8),
                                        (8, 24, None)])
def test_flash_attention_sweep(hq, hkv, t, s, window):
    b, d = 2, 16
    q = jnp.asarray(RNG.normal(size=(b, hq, t, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), jnp.float32)
    want = ref.attention_naive(q, k, v, causal=True, window=window)
    got_ref = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                      block_kv=8)
    got_pl = ops.flash_attention(q, k, v, causal=True, window=window,
                                 impl="interpret", block_q=8, block_kv=8)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    b, hq, hkv, t, d = 1, 2, 2, 16, 16
    q = jnp.asarray(RNG.normal(size=(b, hq, t, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, impl="interpret", block_q=8,
                              block_kv=8)
    want = ref.attention_naive(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("hq,hkv,page,maxp", [(4, 2, 8, 4), (8, 8, 4, 6),
                                              (8, 2, 16, 2)])
def test_decode_attention_paged_sweep(hq, hkv, page, maxp):
    b, d, pool = 3, 32, 24
    kp = jnp.asarray(RNG.normal(size=(pool, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(pool, page, hkv, d)), jnp.float32)
    lengths = jnp.asarray(RNG.integers(1, page * maxp, size=b), jnp.int32)
    table = np.full((b, maxp), -1, np.int32)
    used = set()
    for i in range(b):
        need = -(-int(lengths[i]) // page)
        for j in range(need):
            pid = next(p for p in RNG.permutation(pool) if p not in used)
            used.add(pid)
            table[i, j] = pid
    table = jnp.asarray(table)
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    o_ref, lse_ref = ref.decode_attention_paged_ref(q, kp, vp, table,
                                                    lengths)
    o_pl, lse_pl = ops.decode_attention(q, kp, vp, table, lengths,
                                        impl="interpret")
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lse_pl), np.asarray(lse_ref),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_combine_partial_attention_is_exact(n_shards):
    """Sharded partial-softmax + SRQ combine == unsharded attention."""
    b, h, d, s = 2, 2, 8, 8 * n_shards
    rng = np.random.default_rng(n_shards)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    lengths = jnp.full((b,), s, jnp.int32)
    o_full, _ = ref.decode_attention_naive(q, k, v, lengths)
    parts, lses = [], []
    for i in range(n_shards):
        ks = k[:, i * 8:(i + 1) * 8]
        vs = v[:, i * 8:(i + 1) * 8]
        o, lse = ref.decode_attention_naive(q, ks, vs,
                                            jnp.full((b,), 8, jnp.int32))
        parts.append(o)
        lses.append(lse)
    o_comb = ref.combine_partial_attention(jnp.stack(parts),
                                           jnp.stack(lses))
    np.testing.assert_allclose(np.asarray(o_comb), np.asarray(o_full),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("h,g,n,p,chunk", [(4, 2, 6, 8, 8), (2, 1, 4, 16, 4),
                                           (8, 8, 8, 8, 16)])
def test_ssd_sweep(h, g, n, p, chunk):
    b, t = 2, 32
    x = jnp.asarray(RNG.normal(size=(b, t, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, t, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, t, g, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(size=(b, t, g, n)), jnp.float32)
    y0, h0 = ref.ssd_naive(x, dt, a, bb, cc)
    y1, h1 = ref.ssd_chunked_ref(x, dt, a, bb, cc, chunk=chunk)
    y2, h2 = ops.ssd(x, dt, a, bb, cc, chunk=chunk, impl="interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h0), rtol=2e-4,
                               atol=2e-4)


def test_ssd_state_carry_matches_decode_recurrence():
    """Chunked h_T must equal step-by-step decode recurrence state."""
    b, t, h, p, g, n = 1, 16, 2, 4, 1, 4
    x = jnp.asarray(RNG.normal(size=(b, t, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, t, h)), jnp.float32)
    a = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bb = jnp.asarray(RNG.normal(size=(b, t, g, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(size=(b, t, g, n)), jnp.float32)
    _, h_chunk = ref.ssd_chunked_ref(x, dt, a, bb, cc, chunk=8)
    _, h_seq = ref.ssd_naive(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq),
                               rtol=1e-4, atol=1e-4)
