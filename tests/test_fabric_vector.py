"""Vectorized fabric engine: equivalence with the scalar driver.

The contract under test (ISSUE 2 acceptance):

* a 1-sender/1-receiver vectorized fabric matches ``run_sim`` goodput;
* the float64 numpy backend reproduces scalar ``run_fabric`` essentially
  exactly (same batch-fluid semantics, same arithmetic);
* the float32 jax backend matches scalar per-flow goodput and incast
  completion to <=1e-3 relative on the incast-8 and storage-mix
  scenarios;
* property tests: vectorized-vs-scalar agreement on random small
  topologies/flow sets, and ECN-mark monotonicity in the knee threshold
  on :class:`OutputPort`.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import simulator as S
from repro.fabric import scenarios as SC
from repro.fabric import topology
from repro.fabric.fabric import Flow, FabricConfig, run_fabric
from repro.fabric.scenarios import fabric_grid
from repro.fabric.switch import OutputPort, SwitchConfig
from repro.fabric.vector import FabricSweepParams, run_fabric_sweep

SIM_S = 0.015


def _scalar_arrays(scens):
    """Stack scalar run_fabric results grid-style for comparison."""
    res = [sc.run() for sc in scens]
    F = len(scens[0].flows)
    return res, {
        "flow_goodput_gbps": np.array(
            [[r.flow_goodput_gbps[f] for f in range(F)] for r in res]),
        "flow_completion_us": np.array(
            [[r.flow_completion_us[f] for f in range(F)] for r in res]),
        "incast_completion_us": np.array(
            [r.incast_completion_us for r in res]),
        "victim_goodput_gbps": np.array(
            [r.victim_goodput_gbps for r in res]),
        "pause_fanout": np.array([r.pause_fanout for r in res]),
        "ecn_marked_bytes": np.array([r.ecn_marked_bytes for r in res]),
        "switch_dropped_bytes": np.array(
            [r.switch_dropped_bytes for r in res]),
    }


def _maxrel(a, b):
    m = np.isfinite(a) & np.isfinite(b)
    assert (np.isfinite(a) == np.isfinite(b)).all(), \
        "finite/inf pattern mismatch"
    if not m.any():
        return 0.0
    return float(np.max(np.abs(a[m] - b[m])
                        / np.maximum(np.abs(b[m]), 1e-9)))


@pytest.fixture(scope="module")
def incast8():
    scens, _ = fabric_grid(
        lambda mode, pfc: SC.incast(n_senders=8, mode=mode, pfc=pfc,
                                    burst_mb=1.0, sim_time_s=SIM_S),
        mode=["ddio", "jet"], pfc=[False, True])
    _, ref = _scalar_arrays(scens)
    return scens, ref


@pytest.fixture(scope="module")
def storage():
    """One grid per storage kind (client counts differ, so the kinds
    cannot share a topology structure): kind -> (scenarios, scalar ref)."""
    grids = {}
    for kind in ("oltp", "olap", "backup"):
        scens, _ = fabric_grid(
            lambda mode, kind=kind: SC.storage_mix(kind, mode=mode,
                                                   sim_time_s=0.01),
            mode=["ddio", "jet"])
        _, ref = _scalar_arrays(scens)
        grids[kind] = (scens, ref)
    return grids


# --------------------------------------------------------------------------- #
# equivalence anchors
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@pytest.mark.parametrize("mode", ["ddio", "jet"])
def test_single_pair_matches_run_sim(mode):
    ref = S.run_sim(S.testbed_100g(mode, sim_time_s=0.005))
    sc = SC.single_pair(mode, sim_time_s=0.005)
    for backend, tol in (("numpy", 1e-9), ("jax", 1e-3)):
        out = run_fabric_sweep([sc], backend=backend)
        got = out["recv_goodput_gbps"][0, 0]
        assert got == pytest.approx(ref.goodput_gbps, rel=tol), backend


@pytest.mark.slow
def test_numpy_backend_exact_vs_scalar(incast8):
    scens, ref = incast8
    out = run_fabric_sweep(scens, backend="numpy")
    # same batch-fluid semantics in float64: essentially bit-equal
    assert _maxrel(out["flow_goodput_gbps"],
                   ref["flow_goodput_gbps"]) < 1e-9
    assert _maxrel(out["flow_completion_us"],
                   ref["flow_completion_us"]) == 0.0
    np.testing.assert_array_equal(out["pause_fanout"],
                                  ref["pause_fanout"])
    assert _maxrel(out["ecn_marked_bytes"],
                   ref["ecn_marked_bytes"]) < 1e-9
    assert _maxrel(out["switch_dropped_bytes"],
                   ref["switch_dropped_bytes"]) < 1e-9


def test_jax_backend_matches_scalar_incast8(incast8):
    scens, ref = incast8
    out = run_fabric_sweep(scens, backend="jax")
    # ISSUE 2 acceptance: <=1e-3 relative on per-flow goodput and
    # incast completion
    assert _maxrel(out["flow_goodput_gbps"],
                   ref["flow_goodput_gbps"]) <= 1e-3
    assert _maxrel(out["flow_completion_us"],
                   ref["flow_completion_us"]) <= 1e-3
    assert _maxrel(out["incast_completion_us"],
                   ref["incast_completion_us"]) <= 1e-3
    assert _maxrel(out["victim_goodput_gbps"],
                   ref["victim_goodput_gbps"]) <= 1e-3
    np.testing.assert_array_equal(out["pause_fanout"],
                                  ref["pause_fanout"])
    # PFC points pause the fabric, lossy points drop — both reproduced
    assert out["pause_fanout"].max() >= 2
    assert out["switch_dropped_bytes"].max() > 0


@pytest.mark.slow
def test_jax_backend_matches_scalar_storage(storage):
    for kind, (scens, ref) in storage.items():
        out = run_fabric_sweep(scens, backend="jax")
        assert _maxrel(out["flow_goodput_gbps"],
                       ref["flow_goodput_gbps"]) <= 1e-3, kind
        # open-loop storage flows never complete: inf in both engines
        assert not np.isfinite(out["flow_completion_us"]).any()
        assert not np.isfinite(ref["flow_completion_us"]).any()


@pytest.mark.slow
def test_victim_goodput_no_nan(incast8):
    scens, ref = incast8
    out = run_fabric_sweep(scens, backend="numpy")
    assert out["has_victim"].all()
    # no victim flow -> 0.0 with the flag cleared, never NaN
    plain = SC.incast(n_senders=2, with_victim=False, sim_time_s=0.002)
    r = plain.run()
    assert not r.has_victim
    assert r.victim_goodput_gbps == 0.0
    assert r.tagged_goodput("victim") == 0.0
    assert not r.has_tag("victim")
    assert r.has_tag("incast")
    v = run_fabric_sweep([plain], backend="numpy")
    assert not v["has_victim"].any()
    assert v["victim_goodput_gbps"][0] == 0.0


# --------------------------------------------------------------------------- #
# packing validation
# --------------------------------------------------------------------------- #
def test_grid_must_share_structure():
    a = SC.incast(n_senders=2, sim_time_s=0.002)
    b = SC.incast(n_senders=4, sim_time_s=0.002)
    with pytest.raises(ValueError):
        FabricSweepParams.from_scenarios([a, b])
    c = SC.incast(n_senders=2, sim_time_s=0.004)
    with pytest.raises(ValueError):
        FabricSweepParams.from_scenarios([a, c])
    with pytest.raises(ValueError):
        run_fabric_sweep([])
    with pytest.raises(ValueError):
        run_fabric_sweep([a], backend="torch")


def test_grid_rejects_membw_schedule():
    sc = SC.single_pair("ddio", sim_time_s=0.002,
                        cpu_membw_schedule=lambda t: 1000.0)
    with pytest.raises(ValueError):
        run_fabric_sweep([sc], backend="numpy")


# --------------------------------------------------------------------------- #
# property: vectorized == scalar on random small fabrics
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(st.integers(1, 2), st.integers(2, 3), st.integers(1, 2),
       st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.integers(0, 3), st.booleans()),
                min_size=1, max_size=4),
       st.booleans())
def test_vector_matches_scalar_on_random_fabrics(n_leaves, per_leaf,
                                                 n_spines, flow_specs,
                                                 pfc):
    topo = topology.clos(n_leaves=n_leaves, hosts_per_leaf=per_leaf,
                         n_spines=n_spines if n_leaves > 1 else n_spines,
                         host_gbps=100.0, uplink_gbps=200.0)
    hosts = topo.hosts
    flows = []
    for si, di, load, closed in flow_specs:
        src = hosts[si % len(hosts)]
        dst = hosts[di % len(hosts)]
        if src == dst:
            dst = hosts[(di + 1) % len(hosts)]
            if src == dst:
                continue
        flows.append(Flow(
            src=src, dst=dst,
            offered_gbps=None if load == 0 else 20.0 * load,
            burst_bytes=200e3 if closed else None,
            tag="t"))
    if not flows:
        return
    fcfg = FabricConfig(sim_time_s=0.0006,
                        switch=SwitchConfig(pfc_enabled=pfc,
                                            port_buffer_bytes=1 << 19))
    ref = run_fabric(topo, flows, fcfg)
    sc = SC.Scenario(name="rand", topology=topo, flows=flows, fabric=fcfg)
    out = run_fabric_sweep([sc], backend="numpy")
    F = len(flows)
    gp_ref = np.array([ref.flow_goodput_gbps[f] for f in range(F)])
    assert np.allclose(out["flow_goodput_gbps"][0], gp_ref,
                       rtol=1e-9, atol=1e-9)
    cp_ref = np.array([ref.flow_completion_us[f] for f in range(F)])
    got = out["flow_completion_us"][0]
    assert (np.isfinite(got) == np.isfinite(cp_ref)).all()
    fin = np.isfinite(cp_ref)
    assert np.allclose(got[fin], cp_ref[fin])
    assert out["pause_fanout"][0] == ref.pause_fanout
    assert out["ecn_marked_bytes"][0] == pytest.approx(
        ref.ecn_marked_bytes, rel=1e-9, abs=1e-6)
    assert out["switch_dropped_bytes"][0] == pytest.approx(
        ref.switch_dropped_bytes, rel=1e-9, abs=1e-6)


# --------------------------------------------------------------------------- #
# property: ECN marks are monotone in the knee threshold
# --------------------------------------------------------------------------- #
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 9), st.integers(1, 9),
       st.lists(st.tuples(st.integers(0, 3), st.integers(1, 300),
                          st.booleans()),
                min_size=1, max_size=20))
def test_port_ecn_marks_monotone_in_knee(k1, k2, events):
    """Lowering the ECN knee can only mark more bytes, never fewer, for
    the same enqueue/drain pattern."""
    lo, hi = sorted((k1, k2))
    marked = []
    for k in (lo, hi):
        port = OutputPort(
            topology.Link("a", "b", 80.0),
            SwitchConfig(port_buffer_bytes=1 << 20,
                         ecn_kmin_frac=k / 10.0))
        for fid, kb, drain in events:
            port.enqueue(fid, kb << 10, 0.0, None)
            if drain:
                port.drain(10.0)
        marked.append(port.marked_bytes)
    assert marked[0] >= marked[1] - 1e-9
