"""Recycle-controller model (paper §4.2) + Jet service facade (§3)."""
import pytest

from repro.core.escape import Action, EscapeConfig
from repro.core.jet import JetConfig, JetService, QoS
from repro.core.recycle import (RecycleModel, little_law_bytes,
                                paper_default, paper_unoptimized,
                                slice_message)


def test_littles_law_paper_example():
    # paper §2.2: 200 Gbps x 200 us -> 5 MB
    assert little_law_bytes(200.0, 200.0) == pytest.approx(5e6, rel=0.01)


def test_slice_message():
    s = slice_message(10_000)
    assert sum(s) == 10_000 and max(s) <= 4096
    assert len(slice_message(4096)) == 1


def test_optimizations_reduce_timespan():
    """Each of the paper's three accelerations must shrink the slot-holding
    time; all three together must dominate."""
    base = paper_unoptimized()
    msg = 256 << 10
    t_base = base.slot_holding_time_us(msg)
    import dataclasses
    t_thread = dataclasses.replace(base, threads=4).slot_holding_time_us(msg)
    t_pipe = dataclasses.replace(base, pipelined=True).slot_holding_time_us(
        msg)
    t_simpl = dataclasses.replace(base, crc_offload=True,
                                  struct_serialization=True
                                  ).slot_holding_time_us(msg)
    t_all = paper_default().slot_holding_time_us(msg)
    assert t_thread < t_base
    assert t_pipe < t_base
    assert t_simpl < t_base
    assert t_all < min(t_thread, t_pipe, t_simpl)
    # pipelining is the big lever: slot time becomes O(slice), not O(message)
    assert t_pipe < t_base / 10


def test_pool_sizing_fits_12mb():
    """With the optimized recycle path + jitter headroom, the paper's 12 MB
    pool sustains 200 Gbps (its feasibility claim)."""
    m = paper_default()
    need = m.required_pool_bytes(200.0, 256 << 10, headroom=2.0)
    assert need <= 12 << 20


def test_jet_workflow_roundtrip():
    jet = JetService(JetConfig(pool_bytes=1 << 20))
    jet.register(1, QoS.NORMAL)
    xid = jet.request(1, 300 << 10, now=0.0)
    admitted = jet.pump(0.0)
    assert [t.xfer_id for t in admitted] == [xid]
    assert jet.pool.available_bytes < 1 << 20
    jet.complete(xid, 1.0)
    assert jet.pool.available_bytes == 1 << 20      # swift recycle


def test_jet_qos_priority_and_fallback():
    jet = JetService(JetConfig(pool_bytes=256 << 10))
    jet.register(1, QoS.LOW)
    jet.register(2, QoS.HIGH)
    jet.request(1, 200 << 10, now=0.0)
    hi = jet.request(2, 200 << 10, now=0.0)
    admitted = jet.pump(0.0)
    # HIGH admitted first even though LOW was requested earlier
    assert admitted and admitted[0].xfer_id == hi
    # LOW falls back to memory buffers when the pool can't host it (§5)
    jet.pump(0.0)
    assert jet.memory_fallbacks == 1


def test_jet_small_message_classification():
    jet = JetService()
    jet.register(1)
    x = jet.request(1, 1024, now=0.0)
    t = jet.pump(0.0)
    assert t[0].small                                # SEND/RECV + SRQ path


# --------------------------------------------------------------------------- #
# admission edge cases
# --------------------------------------------------------------------------- #
def test_low_qos_fallback_counts_and_leaves_pool_untouched():
    """§5: oversized LOW transfers all fall back to DRAM (counted per
    transfer); >= NORMAL QoS waits in queue instead of falling back."""
    jet = JetService(JetConfig(pool_bytes=256 << 10))
    jet.register(1, QoS.LOW)
    jet.register(2, QoS.NORMAL)
    for _ in range(3):
        jet.request(1, 300 << 10, now=0.0)      # footprint > whole pool
    jet.request(2, 300 << 10, now=0.0)
    admitted = jet.pump(0.0)
    assert admitted == []
    assert jet.memory_fallbacks == 3
    assert jet.stats()["memory_fallbacks"] == 3
    assert jet.pool.available_bytes == 256 << 10    # nothing allocated
    assert jet.stats()["live_transfers"] == 0       # NORMAL still queued


def test_max_concurrent_transfers_backpressure():
    """Admission stops at max_concurrent_transfers even with pool space;
    each completion re-opens exactly one admission slot (FIFO)."""
    jet = JetService(JetConfig(pool_bytes=4 << 20,
                               max_concurrent_transfers=2))
    jet.register(1, QoS.NORMAL)
    ids = [jet.request(1, 64 << 10, now=0.0) for _ in range(5)]
    admitted = jet.pump(0.0)
    assert [t.xfer_id for t in admitted] == ids[:2]
    assert jet.stats()["live_transfers"] == 2
    jet.complete(ids[0], 1.0)
    assert [t.xfer_id for t in jet.pump(1.0)] == [ids[2]]
    assert jet.stats()["live_transfers"] == 2


def test_complete_after_escape_copy_eviction():
    """An escape COPY evicts a straggler transfer's slots and tick_escape
    drops its bookkeeping; the app's later complete() must be a graceful
    no-op and the pool must end fully recycled."""
    cfg = JetConfig(pool_bytes=256 << 10,
                    escape=EscapeConfig(cache_safe=0.99, cache_danger=0.0,
                                        mem_esc_bytes=0, credit=0.1,
                                        straggler_age=1e-6))
    jet = JetService(cfg)
    jet.register(1, QoS.NORMAL)
    xid = jet.request(1, 200 << 10, now=0.0)
    assert jet.pump(0.0)                         # admitted, pool now tight
    acts = jet.tick_escape(now=10.0)             # replace budget is 0 -> COPY
    assert any(a is Action.COPY for a, _ in acts)
    assert jet.stats()["live_transfers"] == 0    # bookkeeping dropped
    jet.complete(xid, now=11.0)                  # must not raise
    assert jet.pool.available_bytes == 256 << 10
    # double-complete is also inert
    jet.complete(xid, now=12.0)
