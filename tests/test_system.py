"""System-level integration tests: assigned-config fidelity, end-to-end
training convergence, dry-run artifact coverage, benchmark harness claims
and roofline arithmetic."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, tiny_config
from repro.configs.base import ShapeConfig

REPO = os.path.join(os.path.dirname(__file__), "..")


# --------------------------------------------------------------------------- #
# assigned-architecture fidelity: exact values from the assignment table
# --------------------------------------------------------------------------- #
ASSIGNED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
    "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
}


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_assigned_config_values(name):
    cfg = get_arch(name)
    want = ASSIGNED[name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == want, f"{name}: {got} != {want}"


def test_assigned_special_features():
    assert get_arch("llama4-maverick-400b-a17b").num_experts == 128
    assert get_arch("llama4-scout-17b-a16e").num_experts == 16
    assert get_arch("chatglm3-6b").rope_fraction == 0.5
    assert get_arch("h2o-danube-1.8b").sliding_window
    assert get_arch("gemma-7b").head_dim == 256
    assert get_arch("gemma-7b").mlp == "geglu"
    assert get_arch("musicgen-large").num_codebooks == 4
    assert get_arch("xlstm-125m").xlstm
    assert get_arch("llama-3.2-vision-11b").cross_attn_every > 0
    assert get_arch("zamba2-1.2b").ssm_state == 64
    # long-context eligibility: only the sub-quadratic archs
    sub = {n for n in ARCHS if get_arch(n).subquadratic}
    assert sub == {"h2o-danube-1.8b", "xlstm-125m", "zamba2-1.2b"}


def test_param_counts_match_public_sizes():
    """Total parameter counts land near the public model sizes (matmul
    params only — embeddings excluded — so bands are loose)."""
    bands = {
        "chatglm3-6b": (5.0e9, 7.5e9),
        "gemma-7b": (6.5e9, 9.5e9),
        "starcoder2-15b": (13e9, 17e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "llama4-scout-17b-a16e": (90e9, 115e9),     # 16e total ~109B
        "llama4-maverick-400b-a17b": (350e9, 430e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
    }
    for name, (lo, hi) in bands.items():
        total, active = get_arch(name).param_counts()
        assert lo < total < hi, f"{name}: {total/1e9:.2f}B not in band"
        assert active <= total
    # MoE active params: scout ~16-17B active of ~109B total
    total, active = get_arch("llama4-scout-17b-a16e").param_counts()
    assert active < 0.25 * total


# --------------------------------------------------------------------------- #
# end-to-end: tiny model trains and the loss actually decreases
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    from repro.data import pipeline
    from repro.optim import adamw
    from repro.parallel.sharding import single_device_ctx
    from repro.train import loop as loop_mod

    cfg = tiny_config(ARCHS["h2o-danube-1.8b"])
    shape = ShapeConfig("t", "train", 64, 8)
    data = pipeline.for_arch(cfg, shape)
    out = loop_mod.run(
        cfg, single_device_ctx(), adamw.OptConfig(lr=3e-3, total_steps=150),
        loop_mod.LoopConfig(total_steps=150, ckpt_every=1000,
                            ckpt_dir=str(tmp_path), log_every=25),
        data, jax.random.key(0))
    hist = out["history"]
    # learnable synthetic structure: loss must fall well below the start
    assert hist[-1]["loss"] < 0.75 * hist[0]["loss"], hist


# --------------------------------------------------------------------------- #
# dry-run artifact coverage (deliverable e): 33 cells x 2 meshes, all OK
# --------------------------------------------------------------------------- #
def _dryrun_records():
    files = glob.glob(os.path.join(REPO, "experiments", "dryrun", "*.json"))
    return [json.load(open(p)) for p in files]


def test_dryrun_coverage_complete():
    recs = [r for r in _dryrun_records() if r.get("tag", "") == ""]
    if not recs:
        pytest.skip("dry-run artifacts not generated yet")
    cells = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
    # 10 archs x (train, prefill, decode) + 3 sub-quadratic x long_500k
    assert len(cells) == 66, f"expected 66 cells, got {len(cells)}"
    assert all(r["ok"] for r in recs), [
        (r["arch"], r["shape"], r["mesh"]) for r in recs if not r["ok"]]
    meshes = {r["mesh"] for r in recs}
    assert meshes == {"single", "multi"}


def test_dryrun_multipod_shards_pod_axis():
    recs = [r for r in _dryrun_records()
            if r.get("tag", "") == "" and r["ok"]]
    if not recs:
        pytest.skip("dry-run artifacts not generated yet")
    for r in recs:
        if r["mesh"] == "multi":
            assert r["mesh_shape"] == {"pod": 2, "data": 16, "model": 16}
        else:
            assert r["mesh_shape"] == {"data": 16, "model": 16}
        # collectives were actually emitted (sharded program, not replicated)
        if r["shape"] != "long_500k":      # batch-1 decode may be all-local
            assert r["collective_total_per_device"] > 0, (
                r["arch"], r["shape"], r["mesh"])


@pytest.mark.slow
def test_dryrun_cli_end_to_end(tmp_path):
    """The dry-run CLI lowers + compiles + records a cell in a fresh
    subprocess (8 placeholder devices, custom 2x4 mesh)."""
    import subprocess
    import sys
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "xlstm-125m", "--shape", "train_4k", "--mesh", "single",
         "--out", str(tmp_path), "--force",
         "--variant", '{"tag":"clitest","mesh_shape":[2,4]}'],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path /
                         "xlstm-125m__train_4k__single__clitest.json"))
    assert rec["ok"] and rec["flops_per_device"] > 0
    assert rec["mesh_shape"] == {"data": 2, "model": 4}


# --------------------------------------------------------------------------- #
# benchmark harness: paper-claim bands (C4, C8) via the public bench API
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_bench_receiver_datapath_claims():
    import sys
    sys.path.insert(0, REPO)
    from benchmarks import bench_receiver_datapath as B
    rows = B.run()
    idx = {(r["testbed"], r["mode"], r["msg_kb"]): r for r in rows}
    for bed in ("25g_pfc", "100g_pfcfree"):
        jet = idx[(bed, "jet", 256)]
        ddio = idx[(bed, "ddio", 256)]
        assert jet["goodput_gbps"] > 1.5 * ddio["goodput_gbps"]
        assert jet["pfc_pause_us"] == 0
    # C3b: doubling DDIO ways does not rescue the baseline
    d2 = next(r for r in rows if r["mode"] == "ddio_2x_ways")
    d1 = idx[("100g_pfcfree", "ddio", 1024)]
    assert d2["goodput_gbps"] < 1.15 * d1["goodput_gbps"]


def test_bench_hpc_collectives_bands():
    import sys
    sys.path.insert(0, REPO)
    from benchmarks import bench_hpc_collectives as B
    rows = {r["collective"]: r for r in B.run()}
    # within ~8 points of the paper's fig 13 and correctly ordered
    assert abs(rows["all-to-all"]["improvement_pct"] - 35.1) < 8
    assert abs(rows["all-gather"]["improvement_pct"] - 25.0) < 8
    assert abs(rows["all-reduce"]["improvement_pct"] - 5.5) < 8
    assert rows["all-to-all"]["improvement_pct"] > \
        rows["all-gather"]["improvement_pct"] > \
        rows["all-reduce"]["improvement_pct"]


# --------------------------------------------------------------------------- #
# roofline arithmetic
# --------------------------------------------------------------------------- #
def test_roofline_terms():
    import sys
    sys.path.insert(0, REPO)
    from benchmarks import roofline as R
    recs = R.load("single", "")
    if not recs:
        pytest.skip("dry-run artifacts not generated yet")
    rows = [R.analyze_record(r) for r in recs]
    assert len(rows) == 33
    for r in rows:
        assert r["compute_s"] >= 0 and r["memory_s"] >= 0
        assert r["bound"] in ("compute", "memory", "collective")
        assert 0 <= r["roofline_frac"] <= 1.0 + 1e-9
        # useful-FLOP ratio sane: not >2.2x and not absurdly tiny for train
        if r["shape"] == "train_4k":
            assert 0.3 < r["useful_ratio"] < 2.2, r
    # the MODEL_FLOPS convention: train >= 3x prefill per token
    by = {(r["arch"], r["shape"]): r for r in rows}
    t = by[("chatglm3-6b", "train_4k")]["model_gflops_dev"]
    p = by[("chatglm3-6b", "prefill_32k")]["model_gflops_dev"]
    # train_4k: 1M tokens x 6ND; prefill_32k: 1M tokens x 2ND -> ratio 3
    assert abs(t / p - 3.0) < 0.2
