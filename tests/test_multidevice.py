"""Multi-device integration tests (8 host CPU devices, subprocess).

Covers: MoE EP == dense oracle, capacity escape, jet staged collectives
(ring allgather-matmul / reduce-scatter / windowed allgather / SRQ combine),
compressed psum with error feedback, distributed train step == single-device,
and elastic checkpoint reshard.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multidev_driver.py")],
        env=env, capture_output=True, text=True, timeout=1150)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "multi-device driver failed"
