"""Pod-scale Clos fabrics (ISSUE 9): 3-level topology construction,
three-engine equivalence on the sparse-incidence vector engine, and
the edge-case regressions that rode along:

* partially-wired fabrics: wiring-restricted candidate sets and a clear
  ``ValueError`` on unroutable pairs (instead of a ``KeyError`` on a
  nonexistent link);
* zero-uptime links leave the ``pause_storm`` / ``uplink_imbalance``
  denominators in both the scalar driver and the vector mirror;
* histogram-domain overflow is explicit (``overflow_count``, widened
  error bound, percentile-as-lower-bound) instead of a silent midpoint
  below the true latency.

Equivalence contract (same as the 2-tier suite): the float64 numpy
backend reproduces scalar ``run_fabric`` essentially exactly (<1e-9),
the float32 jax backend tracks numpy to <=5e-4 — including a scheduled
failure + flap under per-TC PFC, where the sparse engine's packed
fail/flap windows must agree with the scalar tick loop.
"""
import math

import numpy as np
import pytest

from repro.core.datapath import QoS
from repro.fabric import scenarios as SC
from repro.fabric.fabric import FabricConfig, Flow
from repro.fabric.messages import (HIST_MAX_US, LogHistogram,
                                   MessageConfig, MessageTracker,
                                   percentile_from_counts)
from repro.fabric.routing import RoutingConfig
from repro.fabric.scenarios import Scenario, _recv_factory
from repro.fabric.switch import SwitchConfig
from repro.fabric.topology import Topology, _bidi, make_pod_clos
from repro.fabric.vector import run_fabric_sweep

SIM_S = 0.002

# outputs every engine must agree on
KEYS = ("flow_goodput_gbps", "flow_completion_us",
        "incast_completion_us", "victim_goodput_gbps", "pause_fanout",
        "ecn_marked_bytes", "switch_dropped_bytes")


def _maxrel(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    assert (np.isfinite(a) == np.isfinite(b)).all(), \
        "finite/inf pattern mismatch"
    m = np.isfinite(a) & np.isfinite(b)
    if not m.any():
        return 0.0
    return float(np.max(np.abs(a[m] - b[m])
                        / np.maximum(np.abs(b[m]), 1e-9)))


def _scalar_ref(scens):
    res = [sc.run() for sc in scens]
    F = len(scens[0].flows)
    return res, {
        "flow_goodput_gbps": np.array(
            [[r.flow_goodput_gbps[f] for f in range(F)] for r in res]),
        "flow_completion_us": np.array(
            [[r.flow_completion_us[f] for f in range(F)] for r in res]),
        "incast_completion_us": np.array(
            [r.incast_completion_us for r in res]),
        "victim_goodput_gbps": np.array(
            [r.victim_goodput_gbps for r in res]),
        "pause_fanout": np.array([r.pause_fanout for r in res]),
        "ecn_marked_bytes": np.array([r.ecn_marked_bytes for r in res]),
        "switch_dropped_bytes": np.array(
            [r.switch_dropped_bytes for r in res]),
    }


# --------------------------------------------------------------------------- #
# 3-level topology construction
# --------------------------------------------------------------------------- #
class TestMakePodClos:
    def test_tiers_naming_and_speeds(self):
        t = make_pod_clos(2, 2, 2)
        t.validate()
        assert len(t.hosts) == 8
        assert t.leaves == ["p0l0", "p0l1", "p1l0", "p1l1"]
        assert t.spines == ["p0s0", "p0s1", "p1s0", "p1s1"]
        assert t.super_spines == ["ss0", "ss1"]
        assert t.host_leaf["p1h0_1"] == "p1l0"
        # per-tier link speeds (and their reverse directions)
        assert t.link("p0h0_0", "p0l0").gbps == 100.0
        assert t.link("p0s0", "p0l0").gbps == 200.0
        assert t.link("p0s0", "ss0").gbps == 400.0
        assert t.link("ss0", "p1s0").gbps == 400.0

    def test_single_pod_degenerates_to_two_tier(self):
        t = make_pod_clos(1, 2, 2)
        t.validate()
        assert t.super_spines == []
        # intra-pod cross-leaf route stays 3-hop interior (5 nodes)
        assert len(t.route("p0h0_0", "p0h1_0", 0)) == 5

    def test_cross_pod_routes_are_plane_aligned(self):
        t = make_pod_clos(2, 2, 2)
        r = t.route("p0h0_0", "p1h1_0", 3)
        assert len(r) == 7 and r[3] in t.super_spines
        for sl, sa, ss, sb, dl in t.candidate_paths("p0h0_0", "p1h1_0"):
            # choosing the source pod's spine chooses the plane
            assert sa[-1] == ss[-1] == sb[-1]

    def test_per_tier_oversubscription(self):
        t = make_pod_clos(2, 2, 4, host_gbps=100.0,
                          leaf_spine_gbps=200.0, spine_sspine_gbps=400.0)
        assert t.oversubscription("p0l0") == pytest.approx(1.0)
        assert t.spine_oversubscription("p0s0") == pytest.approx(1.0)

    def test_invalid_dims_raise(self):
        with pytest.raises(ValueError, match="pod-Clos"):
            make_pod_clos(0, 2, 2)
        with pytest.raises(ValueError, match="pod-Clos"):
            make_pod_clos(2, 2, 0)

    def test_fail_and_flap_any_tier(self):
        t = make_pod_clos(2, 2, 2)
        t.fail_link("p0h0_0", "p0l0", at_us=10.0, restore_us=20.0)
        t.fail_link("p0l0", "p0s0", at_us=10.0, restore_us=20.0)
        t.fail_link("p0s0", "ss0", at_us=10.0, restore_us=20.0)
        t.flap_link("p1s1", "ss1", start_us=0.0, period_us=10.0,
                    down_us=4.0)
        t.validate()
        assert not t.link_up_at(("p0s0", "ss0"), 15.0)
        assert not t.link_up_at(("ss0", "p0s0"), 15.0)   # bidi
        assert t.link_up_at(("p0s0", "ss0"), 25.0)
        assert not t.link_up_at(("ss1", "p1s1"), 12.0)   # flap down-phase
        with pytest.raises(ValueError, match="no link"):
            t.fail_link("p0l0", "ss0", at_us=1.0)        # not a wired pair


# --------------------------------------------------------------------------- #
# Satellite: partially-wired fabrics (wiring-restricted candidates)
# --------------------------------------------------------------------------- #
class TestPartialWiring:
    def _partial(self, rescue_spine: bool):
        """2 leaves whose local spines do not interconnect them; with
        ``rescue_spine`` a third spine wires to both."""
        links = {}
        _bidi(links, "a0", "l0", 100.0)
        _bidi(links, "b0", "l1", 100.0)
        _bidi(links, "l0", "s0", 200.0)
        _bidi(links, "l1", "s1", 200.0)
        spines = ["s0", "s1"]
        if rescue_spine:
            _bidi(links, "l0", "s2", 200.0)
            _bidi(links, "l1", "s2", 200.0)
            spines.append("s2")
        t = Topology(hosts=["a0", "b0"], leaves=["l0", "l1"],
                     spines=spines, links=links,
                     host_leaf={"a0": "l0", "b0": "l1"})
        t.validate()
        return t

    def test_candidate_spines_restricted_to_wired(self):
        assert self._partial(False).candidate_spines("a0", "b0") == []
        assert self._partial(True).candidate_spines("a0", "b0") == ["s2"]

    def test_route_never_picks_unwired_spine(self):
        t = self._partial(True)
        # every flow id must hash onto the one wired candidate, never
        # KeyError on a nonexistent (leaf, spine) link
        for fid in range(8):
            assert t.route("a0", "b0", fid) == ["a0", "l0", "s2", "l1",
                                                "b0"]

    def test_unroutable_pair_raises_clear_error(self):
        t = self._partial(False)
        with pytest.raises(ValueError, match="unroutable"):
            t.route("a0", "b0", 0)
        with pytest.raises(ValueError, match="unroutable"):
            t.candidate_paths("a0", "b0")


# --------------------------------------------------------------------------- #
# Satellite: zero-uptime links leave the storm/imbalance denominators
# --------------------------------------------------------------------------- #
def _storm(**kw):
    return SC.pod_pfc_storm(pods=2, leaves_per_pod=2, hosts_per_leaf=2,
                            buffer_kb=32.0, sim_time_s=SIM_S, **kw)


class TestZeroUptimeExclusion:
    def test_scalar_dead_link_excluded(self):
        base = _storm().run()
        sc = _storm()
        sc.topology.fail_link("p1l1", "p1s1", at_us=0.0)
        dead = sc.run()
        assert ("p1l1", "p1s1") in dead.dead_links
        assert dead.n_pausable_links < base.n_pausable_links
        # a *late* failure keeps some uptime: not excluded
        sc2 = _storm()
        sc2.topology.fail_link("p1l1", "p1s1",
                               at_us=SIM_S * 1e6 / 2.0)
        assert sc2.run().n_pausable_links == base.n_pausable_links

    def test_vector_mirror_matches_scalar(self):
        sc = _storm()
        sc.topology.fail_link("p1l1", "p1s1", at_us=0.0)
        r = sc.run()
        out = run_fabric_sweep([sc], backend="numpy")
        assert int(out["n_pausable_links"][0]) == r.n_pausable_links
        assert float(out["pause_storm"][0]) == \
            pytest.approx(r.pause_storm(), rel=1e-9)


# --------------------------------------------------------------------------- #
# Satellite: explicit histogram-domain overflow
# --------------------------------------------------------------------------- #
class TestHistogramOverflow:
    def test_loghistogram_overflow_is_explicit(self):
        h = LogHistogram()
        for _ in range(9):
            h.add(10.0)
        h.add(HIST_MAX_US * 4.0)
        assert h.n == 10 and h.overflow_count == 1
        assert math.isinf(h.rel_error_bound())
        # the overflowed rank reports the domain ceiling (a lower
        # bound), not an in-range midpoint below the true latency
        assert h.percentile(99.0) == h.hi
        assert h.percentile(50.0) < h.hi        # in-range ranks intact

    def test_no_overflow_keeps_finite_bound(self):
        h = LogHistogram()
        h.add(10.0)
        assert h.overflow_count == 0
        assert math.isfinite(h.rel_error_bound())

    def test_percentile_from_counts_overflow(self):
        counts = np.zeros((2, 16))
        counts[:, 3] = 10.0
        ov = np.array([0.0, 90.0])
        p99 = percentile_from_counts(counts, 99.0, overflow=ov)
        assert p99[0] < HIST_MAX_US          # pure in-range: midpoint
        assert p99[1] == HIST_MAX_US         # rank lands in overflow
        # a rank inside the in-range mass is unaffected by overflow
        p5 = percentile_from_counts(counts, 5.0, overflow=ov)
        assert p5[0] == p5[1] < HIST_MAX_US

    def test_tracker_counts_overflow_exact_percentile_intact(self):
        tr = MessageTracker(MessageConfig(msg_bytes=1000.0, window=None))
        tr.observe(1.0, injected=1000.0, delivered=0.0, start_us=0.0)
        tr.observe(HIST_MAX_US * 2.0, injected=1000.0, delivered=1000.0)
        assert tr.done == 1 and tr.overflow_count == 1
        assert tr.percentile(50.0) > HIST_MAX_US


# --------------------------------------------------------------------------- #
# Three-engine equivalence on pod fabrics (sparse-incidence engine)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def pod_grid():
    scens, _ = SC.pod_incast_grid(pods=2, leaves_per_pod=2,
                                  hosts_per_leaf=2, burst_mb=0.2,
                                  sim_time_s=SIM_S)
    _, ref = _scalar_ref(scens)
    return scens, ref


def _fail_tc_scenario(flap: bool = False):
    """Cross-pod incast + victim + low-priority flow under per-TC PFC,
    with a mid-window leaf-uplink failure (and optionally a flap on a
    second uplink) — the case where the sparse engine's packed failure
    windows must reproduce the scalar tick loop."""
    topo = make_pod_clos(2, 2, 2)
    topo.fail_link("p0l0", "p0s0", at_us=300.0, restore_us=1200.0)
    if flap:
        topo.flap_link("p1l0", "p1s0", start_us=200.0, period_us=400.0,
                       down_us=150.0)
    flows = [Flow(src=f"p1h{li}_{hi}", dst="p0h0_0", burst_bytes=2e5,
                  qos=QoS.NORMAL, tag="incast")
             for li in range(2) for hi in range(2)]
    flows.append(Flow(src="p0h1_0", dst="p0h0_1", tag="victim"))
    flows.append(Flow(src="p1h0_1", dst="p0h1_1", qos=QoS.LOW))
    fab = FabricConfig(
        sim_time_s=SIM_S,
        switch=SwitchConfig(pfc_enabled=True, per_tc=True),
        receiver_cfg=_recv_factory("ddio", True))
    return Scenario(name="pod_fail_tc" + ("_flap" if flap else ""),
                    topology=topo, flows=flows, fabric=fab)


class TestPodEquivalence:
    def test_numpy_matches_scalar(self, pod_grid):
        scens, ref = pod_grid
        out = run_fabric_sweep(scens, backend="numpy")
        for k in KEYS:
            assert _maxrel(out[k], ref[k]) < 1e-9, k

    def test_jax_matches_numpy(self, pod_grid):
        scens, _ = pod_grid
        ref = run_fabric_sweep(scens, backend="numpy")
        out = run_fabric_sweep(scens, backend="jax")
        for k in KEYS:
            assert _maxrel(out[k], ref[k]) <= 5e-4, k

    @pytest.mark.parametrize("flap", [False, True])
    def test_failure_per_tc_pfc(self, flap):
        sc = _fail_tc_scenario(flap)
        _, ref = _scalar_ref([sc])
        out = run_fabric_sweep([sc], backend="numpy")
        for k in KEYS:
            assert _maxrel(out[k], ref[k]) < 1e-9, k
        jx = run_fabric_sweep([sc], backend="jax")
        for k in KEYS:
            assert _maxrel(jx[k], out[k]) <= 5e-4, k

    def test_pod_shuffle_crosses_super_spine(self):
        sc = SC.pod_shuffle(pods=2, leaves_per_pod=2, hosts_per_leaf=2,
                            shuffle_mb=0.2, sim_time_s=SIM_S)
        _, ref = _scalar_ref([sc])
        out = run_fabric_sweep([sc], backend="numpy")
        for k in KEYS:
            assert _maxrel(out[k], ref[k]) < 1e-9, k
        # traffic actually transits the super-spine tier
        assert float(out["uplink_util_max"][0]) > 0.0


class TestSparseEngineContract:
    def test_two_tier_sparse_matches_dense_exactly(self):
        scens, _ = SC.fabric_grid(
            lambda mode: SC.incast(n_senders=4, mode=mode, burst_mb=0.2,
                                   sim_time_s=SIM_S),
            mode=["ddio", "jet"])
        dense = run_fabric_sweep(scens, backend="numpy",
                                 incidence="dense")
        sparse = run_fabric_sweep(scens, backend="numpy",
                                  incidence="sparse")
        for k in KEYS:
            np.testing.assert_array_equal(dense[k], sparse[k], err_msg=k)

    def test_dense_rejects_super_spine_topology(self):
        sc = SC.pod_incast(pods=2, leaves_per_pod=2, hosts_per_leaf=2,
                           sim_time_s=SIM_S)
        with pytest.raises(ValueError, match="sparse"):
            run_fabric_sweep([sc], backend="numpy", incidence="dense")

    def test_sparse_rejects_dynamic_features(self):
        sc = SC.incast(n_senders=2, sim_time_s=SIM_S)
        sc.fabric.routing = RoutingConfig(mode="adaptive")
        with pytest.raises(ValueError, match="static_ecmp"):
            run_fabric_sweep([sc], backend="numpy", incidence="sparse")
        sc2 = SC.incast(n_senders=2, sim_time_s=SIM_S)
        sc2.fabric.msg = MessageConfig()
        with pytest.raises(ValueError, match="message layer"):
            run_fabric_sweep([sc2], backend="numpy",
                             incidence="sparse")

    def test_sparse_rejects_adaptive_dt(self):
        sc = SC.pod_incast(pods=2, leaves_per_pod=2, hosts_per_leaf=2,
                           sim_time_s=SIM_S)
        with pytest.raises(ValueError, match="dense-engine only"):
            run_fabric_sweep([sc], backend="jax", adaptive_dt=True)
